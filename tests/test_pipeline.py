"""In-graph control-flow tests for the Pipeflow pipeline subsystem.

Everything here runs on executor condition/multi-condition tasks only —
deterministic seeds, no sleeps (event handshakes with generous timeouts
prove concurrency without timing assumptions).
"""
import threading
from collections import defaultdict

import pytest

from repro.core import ACCEL, Executor, Taskflow, TaskError
from repro.pipeline import (DataPipe, DataPipeline, Pipe, Pipeflow, Pipeline,
                            PipeType)


def _counted_stop(n):
    """First-pipe body admitting exactly n tokens."""
    def admit(pf):
        if pf.token >= n:
            pf.stop()
    return admit


# --------------------------------------------------------------- token order
def test_tokens_visit_stages_in_order(executor):
    N, L, S = 23, 3, 4
    lock = threading.Lock()
    log = []

    def mk(s):
        def stage(pf):
            if s == 0 and pf.token >= N:
                pf.stop()
                return
            with lock:
                log.append((s, pf.token, pf.line))
        return stage

    kinds = [PipeType.SERIAL, PipeType.PARALLEL, PipeType.SERIAL,
             PipeType.PARALLEL]
    pl = Pipeline(L, *[Pipe(kinds[s], mk(s)) for s in range(S)])
    pl.run(executor).wait(30)
    assert pl.num_tokens == N

    per_stage = defaultdict(list)
    per_token = defaultdict(list)
    for s, tok, line in log:
        per_stage[s].append(tok)
        per_token[tok].append(s)
        assert line == tok % L  # token t runs on line t % L
    # SERIAL stages see tokens in strict submission order
    assert per_stage[0] == list(range(N))
    assert per_stage[2] == list(range(N))
    # PARALLEL stages see every token exactly once (any order)
    assert sorted(per_stage[1]) == list(range(N))
    assert sorted(per_stage[3]) == list(range(N))
    # every token visits stages in pipeline order
    assert all(per_token[t] == [0, 1, 2, 3] for t in range(N))


def test_serial_stage_admits_one_line_at_a_time(executor):
    N, L = 17, 4
    lock = threading.Lock()
    active = defaultdict(int)
    peak = defaultdict(int)

    def mk(s):
        def stage(pf):
            if s == 0 and pf.token >= N:
                pf.stop()
                return
            with lock:
                active[s] += 1
                peak[s] = max(peak[s], active[s])
            with lock:
                active[s] -= 1
        return stage

    pl = Pipeline(L, Pipe(PipeType.SERIAL, mk(0)),
                  Pipe(PipeType.SERIAL, mk(1)),
                  Pipe(PipeType.SERIAL, mk(2)))
    pl.run(executor).wait(30)
    assert all(peak[s] == 1 for s in range(3)), peak


def test_parallel_stage_overlaps_lines(executor):
    """Two tokens must be able to occupy a PARALLEL stage simultaneously:
    each waits (bounded) for the other's arrival — deadlock-free only if the
    scheduler really overlaps the lines."""
    arrived = [threading.Event(), threading.Event()]
    ok = []

    def par(pf):
        if pf.token < 2:
            arrived[pf.token].set()
            ok.append(arrived[1 - pf.token].wait(timeout=30))

    pl = Pipeline(2, Pipe(PipeType.SERIAL, _counted_stop(4)),
                  Pipe(PipeType.PARALLEL, par))
    pl.run(executor).wait(30)
    assert ok.count(True) == 2


# ------------------------------------------------------------- stop protocol
def test_stop_mid_stream_drains_in_flight():
    """Observer-based exact accounting: N tokens × S stages + the stopping
    admit + the start condition — nothing more runs after stop()."""
    from repro.core import Observer

    class Count(Observer):
        def __init__(self):
            self.n = 0
            self.lock = threading.Lock()

        def on_entry(self, worker_id, domain, task):
            with self.lock:
                self.n += 1

    obs = Count()
    ex = Executor(domains={"host": 4}, observer=obs)
    N, L, S = 10, 3, 3
    done = defaultdict(int)
    lock = threading.Lock()

    def mk(s):
        def stage(pf):
            if s == 0 and pf.token >= N:
                pf.stop()
                return
            with lock:
                done[pf.token] += 1
        return stage

    pl = Pipeline(L, *[Pipe(PipeType.SERIAL if s != 1 else PipeType.PARALLEL,
                            mk(s)) for s in range(S)])
    pl.run(ex).wait(30)
    ex.shutdown(wait=True)
    assert pl.num_tokens == N
    # every admitted token drained through ALL stages
    assert dict(done) == {t: S for t in range(N)}
    assert obs.n == N * S + 2  # + stopping admit + start condition


def test_stop_outside_first_pipe_raises(executor):
    def bad(pf):
        pf.stop()

    pl = Pipeline(2, Pipe(PipeType.SERIAL, _counted_stop(3)),
                  Pipe(PipeType.SERIAL, bad))
    with pytest.raises(TaskError, match="first pipe"):
        pl.run(executor).wait(30)


# ----------------------------------------------------- zero dedicated threads
def test_pipeline_runs_on_executor_workers_only(executor):
    before = set(threading.enumerate())
    names = set()
    lock = threading.Lock()

    def rec(pf):
        if pf.token >= 12:
            pf.stop()
            return
        with lock:
            names.add(threading.current_thread().name)

    pl = Pipeline(3, Pipe(PipeType.SERIAL, rec),
                  Pipe(PipeType.PARALLEL, lambda pf: names.add(
                      threading.current_thread().name)))
    pl.run(executor).wait(30)
    after = set(threading.enumerate())
    assert names and all(n.startswith("repro-worker-") for n in names)
    assert after - before == set()  # the pipeline spawned ZERO threads


def test_pipe_domain_routes_to_accel_workers():
    ex = Executor(domains={"host": 2, "accel": 1})
    names = defaultdict(set)
    lock = threading.Lock()

    def mk(s):
        def stage(pf):
            if s == 0 and pf.token >= 6:
                pf.stop()
                return
            with lock:
                names[s].add(threading.current_thread().name)
        return stage

    pl = Pipeline(2, Pipe(PipeType.SERIAL, mk(0)),
                  Pipe(PipeType.SERIAL, mk(1), domain=ACCEL))
    pl.run(ex).wait(30)
    ex.shutdown(wait=True)
    assert all("accel" in n for n in names[1]) and names[1]
    assert all("host" in n for n in names[0]) and names[0]


# ------------------------------------------------------------- graph statics
def test_static_cyclic_graph_shape():
    pl = Pipeline(3, Pipe(PipeType.SERIAL, lambda pf: pf.stop()),
                  Pipe(PipeType.PARALLEL, lambda pf: None))
    # L*S multi-condition slots + 1 start condition, built ONCE
    assert pl.taskflow.num_tasks() == 3 * 2 + 1
    dump = pl.taskflow.dump()
    assert "style=dashed" in dump  # every pipeline edge is weak (§3.4)
    assert dump.count("diamond") == 3 * 2 + 1  # all condition-family tasks


def test_pipeline_validation():
    with pytest.raises(ValueError, match="at least one line"):
        Pipeline(0, Pipe(PipeType.SERIAL, lambda pf: None))
    with pytest.raises(ValueError, match="at least one pipe"):
        Pipeline(1)
    with pytest.raises(ValueError, match="first pipe must be SERIAL"):
        Pipeline(1, Pipe(PipeType.PARALLEL, lambda pf: None))


# ------------------------------------------------------------------- re-runs
def test_rerun_continues_token_stream(executor):
    seen = []
    budget = [5]

    def admit(pf):
        if pf.token >= budget[0]:
            pf.stop()
            return
        seen.append(pf.token)

    pl = Pipeline(2, Pipe(PipeType.SERIAL, admit))
    pl.run(executor).wait(30)
    assert seen == list(range(5))
    budget[0] = 12  # restart pattern: drained pipeline re-armed by run()
    pl.run(executor).wait(30)
    assert seen == list(range(12))
    assert pl.num_tokens == 12


def test_reset_while_running_raises(executor):
    gate = threading.Event()

    def admit(pf):
        if pf.token >= 1:
            pf.stop()
            return
        gate.wait(30)

    pl = Pipeline(1, Pipe(PipeType.SERIAL, admit))
    topo = pl.run(executor)
    with pytest.raises(RuntimeError, match="running pipeline"):
        pl.reset()
    gate.set()
    topo.wait(30)
    pl.reset()  # fine once drained


def test_executor_rejects_concurrent_resubmission(executor):
    gate = threading.Event()
    tf = Taskflow("twice")
    tf.static(lambda: gate.wait(30))
    topo = executor.run(tf)
    with pytest.raises(RuntimeError, match="already running"):
        executor.run(tf)
    gate.set()
    topo.wait(30)
    executor.run(tf).wait(30)  # sequential re-run stays legal


# --------------------------------------------------- token-level deferral
def test_defer_parks_until_dependency_completes(executor):
    """Token 2 defers on token 0 while 0 is still mid-pipeline: admission
    pauses (no spinning, no overtaking at the admission point), in-flight
    tokens drain, and the resume re-runs the SAME token number exactly once
    after 0 completes the last pipe."""
    hold0 = threading.Event()
    released_before_resume = []
    admits = []
    deferred = [False]
    lock = threading.Lock()

    def admit(pf):
        if pf.token >= 5:
            pf.stop()
            return
        if pf.token == 2 and not deferred[0]:
            deferred[0] = True
            released_before_resume.append(hold0.is_set())
            pf.defer(0)
            return
        with lock:
            admits.append(pf.token)

    def mid(pf):
        if pf.token == 0:
            hold0.wait(30)

    pl = Pipeline(3, Pipe(PipeType.SERIAL, admit),
                  Pipe(PipeType.PARALLEL, mid),
                  Pipe(PipeType.SERIAL, lambda pf: None))
    topo = pl.run(executor)
    # token 2 is parked on token 0, which is blocked in stage 1 -> the
    # pipeline cannot finish until we release it
    assert not topo.event.wait(0.2)
    hold0.set()
    topo.wait(30)
    assert admits == [0, 1, 2, 3, 4]      # same token resumed, order kept
    assert released_before_resume == [False]  # it really parked first
    assert pl.num_token_deferrals == 1
    assert pl.num_resumes == 1            # resume accounting: exactly once


def test_defer_on_completed_token_reruns_immediately(executor):
    seen = []
    d = [False]

    def admit(pf):
        if pf.token >= 4:
            pf.stop()
            return
        if pf.token == 3 and not d[0]:
            d[0] = True
            pf.defer(0)                   # token 0 completed long ago
            return
        seen.append(pf.token)

    pl = Pipeline(2, Pipe(PipeType.SERIAL, admit))
    pl.run(executor).wait(30)
    assert seen == [0, 1, 2, 3]
    assert pl.num_token_deferrals == 1 and pl.num_resumes == 1


def test_defer_validation(executor):
    with pytest.raises(TaskError, match="first pipe"):
        pl = Pipeline(2, Pipe(PipeType.SERIAL, _counted_stop(2)),
                      Pipe(PipeType.SERIAL, lambda pf: pf.defer(0)))
        pl.run(executor).wait(30)
    with pytest.raises(TaskError, match="itself"):
        pl = Pipeline(2, Pipe(PipeType.SERIAL,
                              lambda pf: pf.defer(pf.token)))
        pl.run(executor).wait(30)
    with pytest.raises(TaskError, match="un-minted"):
        pl = Pipeline(2, Pipe(PipeType.SERIAL, lambda pf: pf.defer(7)))
        pl.run(executor).wait(30)


def test_defer_resumes_across_reruns(executor):
    """The monotone token stream + completion watermark survive the re-arm
    path: a second run() can defer on tokens completed in the FIRST run."""
    log = []
    budget = [3]
    d = [False]

    def admit(pf):
        if pf.token >= budget[0]:
            pf.stop()
            return
        if pf.token == 4 and not d[0]:
            d[0] = True
            pf.defer(1)                   # completed in run 1
            return
        log.append(pf.token)

    pl = Pipeline(2, Pipe(PipeType.SERIAL, admit))
    pl.run(executor).wait(30)
    budget[0] = 6
    pl.run(executor).wait(30)
    assert log == [0, 1, 2, 3, 4, 5]
    assert pl.num_token_deferrals == 1 and pl.num_resumes == 1


# ------------------------------------------------------------ stage_times
def test_stage_times_accumulate_monotone(executor):
    """stage_times sums body wall time per pipe name, over lines AND runs:
    a second run only ever grows the numbers."""
    import time as _time
    budget = [6]

    def admit(pf):
        if pf.token >= budget[0]:
            pf.stop()
            return
        _time.sleep(0.002)

    def work(pf):
        _time.sleep(0.002)

    pl = Pipeline(2, Pipe(PipeType.SERIAL, admit, name="admit"),
                  Pipe(PipeType.PARALLEL, work, name="work"))
    assert pl.stage_times == {"admit": 0.0, "work": 0.0}
    pl.run(executor).wait(30)
    first = pl.stage_times
    # every body slept >= 2ms per visit: 6 admit visits + 6 work visits
    # (the stopping admit adds a 7th, sleepless, visit)
    assert first["admit"] >= 6 * 0.002
    assert first["work"] >= 6 * 0.002
    budget[0] = 12
    pl.run(executor).wait(30)
    second = pl.stage_times
    assert set(second) == {"admit", "work"}
    assert all(second[k] >= first[k] for k in first)  # monotone across runs
    assert second["work"] >= 12 * 0.002


def test_stage_times_no_slot_races_under_parallel(executor):
    """Two lines INSIDE the PARALLEL stage at once (event rendezvous): the
    per-(line, pipe) counters must not lose either line's interval — the
    summed stage time covers both concurrent bodies, not just one."""
    import time as _time
    arrived = [threading.Event(), threading.Event()]
    ok = []

    def par(pf):
        if pf.token < 2:
            arrived[pf.token].set()
            ok.append(arrived[1 - pf.token].wait(timeout=30))
            _time.sleep(0.01)

    pl = Pipeline(2, Pipe(PipeType.SERIAL, _counted_stop(4), name="admit"),
                  Pipe(PipeType.PARALLEL, par, name="par"))
    pl.run(executor).wait(30)
    assert ok.count(True) == 2  # both tokens really overlapped in the stage
    # both overlapped bodies slept 10ms: a lost per-slot update would leave
    # the sum below 20ms
    assert pl.stage_times["par"] >= 2 * 0.01


def test_stage_times_fresh_on_rebuild(executor):
    """A rebuilt Pipeline (the serve engine rebuilds its resident pipeline
    on geometry change) starts from zero, while reset()+rerun of the SAME
    object keeps accumulating (documented: summed over runs)."""
    def mk():
        return Pipeline(2, Pipe(PipeType.SERIAL, _counted_stop(5),
                                name="admit"))

    pl = mk()
    pl.run(executor).wait(30)
    assert pl.stage_times["admit"] > 0.0
    rebuilt = mk()
    assert rebuilt.stage_times == {"admit": 0.0}


def test_stage_times_promote_to_tracer_spans(executor):
    """With a repro.obs.Tracer attached, every pipe-body interval is also a
    span on that line's track, consistent with the stage_times aggregate."""
    from repro.obs import Tracer

    tr = Tracer()
    pl = Pipeline(2, Pipe(PipeType.SERIAL, _counted_stop(4), name="admit"),
                  Pipe(PipeType.PARALLEL, lambda pf: None, name="work"))
    pl.tracer = tr
    pl.run(executor).wait(30)
    spans = tr.spans()
    # 4 tokens x 2 stages + the stopping admit visit
    assert len(spans) == 4 * 2 + 1
    assert {s[1] for s in spans} == {"line0", "line1"}
    assert {s[0] for s in spans} == {"admit", "work"}
    assert all(s[3] >= s[2] for s in spans)
    # span sum == stage_times aggregate (same measurements, two views)
    agg = sum(s[3] - s[2] for s in spans)
    st = pl.stage_times
    assert abs(agg - (st["admit"] + st["work"])) < 1e-6
    # detaching stops recording without disturbing accumulation
    pl.tracer = None
    pl.run(executor).wait(30)
    assert len(tr.spans()) == 4 * 2 + 1


# -------------------------------------------------------------- data passing
def test_data_pipeline_threads_buffers(executor):
    outs = []

    def produce(pf):
        if pf.token >= 9:
            pf.stop()
            return None
        return pf.token

    dp = DataPipeline(3,
                      DataPipe(PipeType.SERIAL, produce),
                      DataPipe(PipeType.PARALLEL, lambda pf, x: x * x + pf.line),
                      DataPipe(PipeType.SERIAL, lambda pf, x: outs.append(x)))
    dp.run(executor).wait(30)
    assert outs == [t * t + (t % 3) for t in range(9)]


def test_prefetcher_get_before_start_self_arms(executor):
    """get() on a never-started executor-mode prefetcher must arm the
    pipeline itself instead of blocking until timeout."""
    from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM

    cfg = DataConfig(vocab_size=32, seq_len=4, global_batch=1, seed=2)
    p = Prefetcher(SyntheticLM(cfg).batch_at, depth=2, executor=executor)
    step, _ = p.get(timeout=30)  # no start(): get() pumps before blocking
    assert step == 0
    p.stop()


def test_prefetcher_is_a_pipeline_client(executor):
    from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM

    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2, seed=1)
    src = SyntheticLM(cfg)
    p = Prefetcher(src.batch_at, depth=3, executor=executor)
    assert p.start()
    steps = [p.get(timeout=30)[0] for _ in range(9)]
    assert steps == list(range(9))  # PARALLEL staging, still in step order
    p.stop()
    # determinism vs the manual drive
    q = Prefetcher(SyntheticLM(cfg).batch_at, depth=3)
    assert q.produce_one()
    import numpy as np
    s0, b0 = q.get(timeout=30)
    assert s0 == 0
    np.testing.assert_array_equal(b0["tokens"],
                                  SyntheticLM(cfg).batch_at(0)["tokens"])
