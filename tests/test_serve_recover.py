"""Kill-and-recover chaos tests: the end-to-end durability acceptance.

Each scenario runs the serve engine in a SUBPROCESS with ``--state-dir``
style durability (journal + snapshot via the engine API), kills it hard
mid-stream — either the deterministic ``crash_at`` fault site
(``os._exit(137)`` at the Nth decode-chunk sync point) or a real SIGKILL
from outside — then starts a FRESH process on the same state directory
and recovers. The acceptance bar (docs/robustness.md): every request the
dead process accepted is either already finished (terminal journal
record — the client got its answer) or replays **bit-identically**
against the gather-oracle reference run. Both the synchronous decode
path and the async lookahead path must pass; they share one sync oracle
because sync/async bit-identity is its own engine invariant.

Subprocess idiom follows test_serve_mesh.py: raw-string scripts that set
env before importing jax, driven by ``_run_sub``.
"""
import os
import signal
import subprocess
import sys
import time

import pytest

# One engine "incarnation": recover whatever a previous incarnation left
# in RECOVER_STATE_DIR, then (unless RECOVER_SUBMIT=0) serve 4 fresh
# requests. Request ids are process-local and deterministic (0..3 in
# submit order), so the oracle run, the crashed run, and the recovery
# run all agree on which request is which.
SERVE_SCRIPT = r"""
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if os.environ.get("RECOVER_ASYNC") == "1":
    os.environ["REPRO_ASYNC_DECODE"] = "1"
import numpy as np
import jax
from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeEngine

cfg = get_config("stablelm-1.6b").smoke()
params = lm.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(7)
prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
           for n in (12, 9, 17, 14)]
eng = ServeEngine(cfg, params, max_batch=2, decode_chunk=2,
                  kv_blocks=64, block_size=8, paged_impl="gather",
                  fault_inject=os.environ.get("RECOVER_FAULTS") or None)
replayed = eng.recover(os.environ["RECOVER_STATE_DIR"])
for old_id in sorted(replayed):
    out = eng.result(replayed[old_id], timeout=300.0)
    print("REPLAYED", old_id, ",".join(map(str, out.tolist())), flush=True)
if os.environ.get("RECOVER_SUBMIT", "1") == "1":
    reqs = [eng.submit(p, 16) for p in prompts]
    for r in reqs:
        out = eng.result(r, timeout=300.0)
        print("DONE", r.id, ",".join(map(str, out.tolist())), flush=True)
eng.drain(deadline_s=30.0)
eng.close()
print("EXIT CLEAN", flush=True)
"""


def _env(state_dir, *, faults=None, submit=True, async_decode=False):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env["RECOVER_STATE_DIR"] = str(state_dir)
    env["RECOVER_FAULTS"] = faults or ""
    env["RECOVER_SUBMIT"] = "1" if submit else "0"
    env["RECOVER_ASYNC"] = "1" if async_decode else "0"
    return env


def _run_sub(env, timeout=600.0):
    return subprocess.run([sys.executable, "-c", SERVE_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=timeout)


def _parse(stdout):
    """stdout -> ({id: tokens} finished, {old_id: tokens} replayed)."""
    done, replayed = {}, {}
    for line in stdout.strip().splitlines():
        parts = line.split()
        if parts and parts[0] == "DONE":
            done[int(parts[1])] = parts[2]
        elif parts and parts[0] == "REPLAYED":
            replayed[int(parts[1])] = parts[2]
    return done, replayed


@pytest.fixture(scope="module")
def oracle(tmp_path_factory):
    """Reference tokens per request id from one clean, uncrashed run."""
    state = tmp_path_factory.mktemp("oracle-state")
    r = _run_sub(_env(state))
    assert r.returncode == 0, r.stderr[-3000:]
    assert r.stdout.strip().splitlines()[-1] == "EXIT CLEAN"
    done, _ = _parse(r.stdout)
    assert sorted(done) == [0, 1, 2, 3]
    return done


def _journal_finished_ids(path):
    """Request ids with a terminal finish record (compact sorted-key
    JSON: ``"id":N,"k":"finish"``) — no engine import needed."""
    import re
    try:
        blob = open(path, "rb").read()
    except OSError:
        return set()
    return {int(m) for m in re.findall(rb'"id":(\d+),"k":"finish"', blob)}


def _assert_recovered(oracle, crashed_out, recovered_out,
                      journal_finished=()):
    """Every accepted request is finished-before-crash or bit-identically
    replayed; none may be lost or answered differently. A request whose
    ``finish`` hit the WAL in the instant before the kill (terminal in
    the journal, output print lost with the process) counts as finished."""
    crash_done, _ = _parse(crashed_out)
    rec_done, rec_replayed = _parse(recovered_out)
    assert not rec_done                         # recovery run submits none
    for rid, want in oracle.items():
        got = crash_done.get(rid) or rec_replayed.get(rid)
        if got is None:
            assert rid in journal_finished, \
                f"request {rid} lost: neither finished nor replayed"
            continue
        assert got == want, f"request {rid} tokens diverged after recovery"
    # nothing already answered gets answered again
    assert not (set(crash_done) & set(rec_replayed))


def _crash_then_recover(state, oracle, *, async_decode):
    crash = _run_sub(_env(state, faults="crash_at:at=3",
                          async_decode=async_decode))
    assert crash.returncode == 137, \
        f"rc={crash.returncode}\n{crash.stderr[-3000:]}"
    assert os.path.exists(os.path.join(str(state), "journal.wal"))
    rec = _run_sub(_env(state, submit=False, async_decode=async_decode))
    assert rec.returncode == 0, rec.stderr[-3000:]
    assert rec.stdout.strip().splitlines()[-1] == "EXIT CLEAN"
    _assert_recovered(oracle, crash.stdout, rec.stdout)
    # recovered incarnation left a rotated journal behind
    assert os.path.exists(os.path.join(str(state),
                                       "journal.wal.replayed"))


@pytest.mark.slow
def test_crash_at_then_recover_sync(oracle, tmp_path):
    _crash_then_recover(tmp_path / "state", oracle, async_decode=False)


@pytest.mark.slow
def test_crash_at_then_recover_async(oracle, tmp_path):
    _crash_then_recover(tmp_path / "state", oracle, async_decode=True)


@pytest.mark.slow
def test_sigkill_then_recover(oracle, tmp_path):
    state = tmp_path / "state"
    jpath = os.path.join(str(state), "journal.wal")
    proc = subprocess.Popen([sys.executable, "-c", SERVE_SCRIPT],
                            env=_env(state), stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)
    # kill only after the WAL shows all 4 submits and decode has started
    # (first_token journaled) so the kill lands mid-stream, not pre-work
    deadline = time.monotonic() + 300.0
    try:
        while time.monotonic() < deadline:
            if proc.poll() is not None:
                break                           # outran us: clean finish
            try:
                blob = open(jpath, "rb").read()
            except OSError:
                blob = b""
            if blob.count(b'"k":"submit"') >= 4 \
                    and blob.count(b'"k":"first_token"') >= 1:
                proc.send_signal(signal.SIGKILL)
                break
            time.sleep(0.02)
        out, err = proc.communicate(timeout=600.0)
    finally:
        proc.kill()
    assert os.path.exists(jpath), err[-3000:]
    rec = _run_sub(_env(state, submit=False))
    assert rec.returncode == 0, rec.stderr[-3000:]
    assert rec.stdout.strip().splitlines()[-1] == "EXIT CLEAN"
    finished = _journal_finished_ids(jpath + ".replayed")
    _assert_recovered(oracle, out, rec.stdout, journal_finished=finished)
