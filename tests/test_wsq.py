import threading

from repro.core import WorkStealingQueue


def test_lifo_owner_fifo_thief():
    q = WorkStealingQueue()
    for i in range(5):
        q.push(i)
    assert q.pop() == 4            # owner: LIFO
    assert q.steal() == 0          # thief: FIFO
    assert len(q) == 3
    assert not q.empty()


def test_empty_returns_none():
    q = WorkStealingQueue()
    assert q.pop() is None
    assert q.steal() is None
    assert q.empty()


def test_concurrent_steals_no_loss_no_dup():
    q = WorkStealingQueue()
    N = 20_000
    for i in range(N):
        q.push(i)
    got = []
    lock = threading.Lock()

    def thief():
        while True:
            t = q.steal()
            if t is None:
                if q.empty():
                    return
                continue
            with lock:
                got.append(t)

    def owner():
        while True:
            t = q.pop()
            if t is None:
                return
            with lock:
                got.append(t)

    ts = [threading.Thread(target=thief) for _ in range(4)]
    ts.append(threading.Thread(target=owner))
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert sorted(got) == list(range(N))  # every task exactly once
