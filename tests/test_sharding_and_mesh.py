"""Sharding rules + a REAL multi-device lower/compile in a subprocess
(the main test process keeps 1 device; the subprocess gets 8 virtual
devices via XLA_FLAGS, mirroring the dry-run mechanics on a small mesh)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import ShardCtx, constrain, param_specs
from repro.models import lm


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert constrain(x, "dp", None) is x


def test_param_specs_rules():
    cfg = get_config("qwen3-14b").smoke()
    shapes = jax.eval_shape(lambda k: lm.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    ctx = ShardCtx(mesh=None)
    specs = param_specs(shapes, ctx)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    by_path = {"/".join(str(getattr(k, "key", k)) for k in path): spec
               for path, spec in flat}
    # stacked blocks get a leading None for the layer dim
    wq = [v for k, v in by_path.items() if k.endswith("wq")][0]
    assert wq[0] is None and len(wq) == 3
    embed = [v for k, v in by_path.items() if k.endswith("embed")][0]
    assert len(embed) == 2


def test_divisibility_guard_drops_axis():
    """vocab 503 (smoke) is not divisible by any axis -> embed spec has no
    mesh axes on dim 0 unless padded_vocab divides."""
    cfg = get_config("stablelm-1.6b").smoke()
    assert cfg.padded_vocab % 256 == 0


MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import dataclasses
from repro.configs import get_config, SHAPES_BY_NAME
from repro.launch.mesh import make_ctx
from repro.train.train_step import train_input_specs, make_decode_step
# jax<0.5 has no jax.sharding.AxisType (axes default to Auto there anyway)
try:
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
except (AttributeError, TypeError):
    mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = make_ctx(mesh)
cfg = dataclasses.replace(get_config("stablelm-1.6b").smoke(),
                          d_model=128, vocab_size=1024, num_heads=8,
                          num_kv_heads=4, head_dim=16, d_ff=256)
shape = dataclasses.replace(SHAPES_BY_NAME["train_4k"], seq_len=64,
                            global_batch=8)
step, specs, _ = train_input_specs(cfg, ctx, shape)
with mesh:
    compiled = jax.jit(step, donate_argnums=(0, 1)).lower(*specs).compile()
    mem = compiled.memory_analysis()
dshape = dataclasses.replace(SHAPES_BY_NAME["decode_32k"], seq_len=64,
                             global_batch=8)
dstep, dspecs, _ = make_decode_step(cfg, ctx, dshape)
with mesh:
    dcomp = jax.jit(dstep, donate_argnums=(1,)).lower(*dspecs).compile()
print(json.dumps({"train_temp": mem.temp_size_in_bytes,
                  "decode_ok": True}))
"""


@pytest.mark.slow
def test_multidevice_lower_compile_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["decode_ok"] and out["train_temp"] > 0
