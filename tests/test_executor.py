import random
import threading
import time

import pytest

from repro.core import (ACCEL, HOST, Executor, Profiler, TaskError, Taskflow)


def test_listing1_static_dag(executor):
    order = []
    tf = Taskflow("demo")
    A, B, C, D = tf.emplace(lambda: order.append("A"),
                            lambda: order.append("B"),
                            lambda: order.append("C"),
                            lambda: order.append("D"))
    A.precede(B, C)
    B.precede(D)
    C.precede(D)
    executor.run(tf).wait()
    assert order[0] == "A" and order[-1] == "D" and len(order) == 4


def test_listing2_subflow_joins(executor):
    seen = []
    tf = Taskflow()
    A = tf.static(lambda: seen.append("A"), name="A")

    def B(sf):
        seen.append("B")
        b1 = sf.static(lambda: seen.append("B1"))
        b2 = sf.static(lambda: seen.append("B2"))
        b3 = sf.static(lambda: seen.append("B3"))
        b3.succeed(b1, b2)

    Bt = tf.dynamic(B)
    C = tf.static(lambda: seen.append("C"), name="C")
    D = tf.static(lambda: seen.append("D"), name="D")
    A.precede(Bt, C)
    D.succeed(Bt, C)
    executor.run(tf).wait()
    i = seen.index
    assert i("B3") > i("B1") and i("B3") > i("B2")
    assert i("D") > i("B3")        # join semantics: D waits for the subflow


def test_detached_subflow(executor):
    seen = []
    done = threading.Event()
    tf = Taskflow()

    def A(sf):
        def slow():
            time.sleep(0.05)
            seen.append("detached")
            done.set()
        sf.static(slow)
        sf.detach()

    At = tf.dynamic(A)
    B = tf.static(lambda: seen.append("B"))
    At.precede(B)
    executor.run(tf).wait()        # detached joins at END of taskflow
    assert done.is_set()
    assert "detached" in seen and "B" in seen


def test_listing3_composition(executor):
    log = []
    inner = Taskflow("inner")
    ia = inner.static(lambda: log.append("iA"))
    ib = inner.static(lambda: log.append("iB"))
    ia.precede(ib)
    outer = Taskflow("outer")
    oc = outer.static(lambda: log.append("oC"))
    mod = outer.composed_of(inner)
    od = outer.static(lambda: log.append("oD"))
    oc.precede(mod)
    mod.precede(od)
    executor.run(outer).wait()
    assert log == ["oC", "iA", "iB", "oD"]


def test_listing4_conditional_cycle(executor):
    hits = {"n": 0}
    tf = Taskflow()
    init = tf.static(lambda: None)

    def flip():
        hits["n"] += 1
        return 1 if hits["n"] >= 7 else 0

    F = tf.condition(flip)
    stop = tf.static(lambda: None)
    init.precede(F)
    F.precede(F, stop)
    executor.run(tf).wait()
    assert hits["n"] == 7


def test_multi_condition(executor):
    seen = []
    tf = Taskflow()
    m = tf.multi_condition(lambda: [0, 2])
    a = tf.static(lambda: seen.append("a"))
    b = tf.static(lambda: seen.append("b"))
    c = tf.static(lambda: seen.append("c"))
    m.precede(a, b, c)
    executor.run(tf).wait()
    assert sorted(seen) == ["a", "c"]


def test_condition_out_of_range_stops(executor):
    seen = []
    tf = Taskflow()
    cond = tf.condition(lambda: 5)
    nxt = tf.static(lambda: seen.append("x"))
    cond.precede(nxt)
    executor.run(tf).wait()
    assert seen == []


def test_run_n_and_run_until(executor):
    cnt = {"n": 0}
    tf = Taskflow()
    tf.static(lambda: cnt.__setitem__("n", cnt["n"] + 1))
    executor.run_n(tf, 5).wait()
    assert cnt["n"] == 5
    executor.run_until(tf, lambda: cnt["n"] >= 9).wait()
    assert cnt["n"] == 9


def test_exception_cancels_topology(executor):
    ran = []
    tf = Taskflow()
    a = tf.static(lambda: ran.append("a"))

    def boom():
        raise ValueError("boom")

    b = tf.static(boom)
    c = tf.static(lambda: ran.append("c"))
    a.precede(b)
    b.precede(c)
    with pytest.raises(TaskError):
        executor.run(tf).wait()
    assert "c" not in ran          # successors of a failed task don't run


def test_no_source_reports_error(executor):
    tf = Taskflow()
    a = tf.static(lambda: None)
    b = tf.static(lambda: None)
    a.precede(b)
    b.precede(a)                   # paper Fig.6 pitfall: no source
    with pytest.raises(TaskError):
        executor.run(tf).wait()


def test_corun_topologies(executor):
    boxes = []
    topos = []
    for _ in range(8):
        tf = Taskflow()
        box = {"n": 0}
        boxes.append(box)
        a = tf.static(lambda box=box: box.__setitem__("n", box["n"] + 1))
        b = tf.static(lambda box=box: box.__setitem__("n", box["n"] + 1))
        a.precede(b)
        topos.append(executor.run(tf))
    for t in topos:
        t.wait()
    assert all(b["n"] == 2 for b in boxes)


def test_heterogeneous_domains():
    seen = []
    ex = Executor(domains={HOST: 2, ACCEL: 2}, devices={ACCEL: [0, 1]})
    try:
        tf = Taskflow()
        h = tf.static(lambda: seen.append("host"), domain=HOST)
        a = tf.static(lambda: seen.append("accel"), domain=ACCEL)
        h.precede(a)
        ex.run(tf).wait()
        assert seen == ["host", "accel"]
        assert ex.domain_workers(ACCEL) == 2
    finally:
        ex.shutdown()


def test_profiler_observer():
    prof = Profiler()
    ex = Executor(domains={HOST: 2}, observer=prof)
    try:
        tf = Taskflow()
        for _ in range(20):
            tf.static(lambda: time.sleep(0.001))
        ex.run(tf).wait()
        s = prof.summary()
        assert s["tasks"] == 20
        assert s["busy_s"] > 0
    finally:
        ex.shutdown()


def test_profiler_per_domain_and_idle_workers():
    """Per-domain aggregation + utilization normalized by every worker that
    REPORTED (sleepers included): with 4 host workers and ~serial 1ms
    tasks, a profiler that only counted task-executing workers would
    overstate utilization whenever some workers never won a task."""
    prof = Profiler()
    ex = Executor(domains={HOST: 4, "accel": 1}, observer=prof)
    try:
        tf = Taskflow()
        prev = None
        for _ in range(10):           # a chain: at most ONE task runnable
            t = tf.static(lambda: time.sleep(0.002))
            if prev is not None:
                prev.precede(t)
            prev = t
        ex.run(tf).wait()
        # settle: give idle workers time to report a sleep hook
        time.sleep(0.05)
        s = prof.summary()
        assert s["tasks"] == 10
        pd = s["per_domain"]
        assert set(pd) <= {HOST, "accel"} and HOST in pd
        assert pd[HOST]["tasks"] == 10
        assert pd[HOST]["busy_s"] > 0
        assert sum(d["tasks"] for d in pd.values()) == s["tasks"]
        assert abs(sum(d["busy_s"] for d in pd.values()) - s["busy_s"]) \
            < 1e-9
        # the accel domain ran nothing; its worker still reported
        if "accel" in pd:
            assert pd["accel"]["tasks"] == 0
        # normalization: every reporting worker counts. A serial chain on a
        # 4-worker domain can never be >= 50% busy per worker; the old
        # len(tasks_executed) normalization reported exactly that whenever
        # fewer than half the workers won tasks.
        assert s["workers"] >= pd[HOST]["workers"] >= 1
        assert s["utilization"] <= 1.0
        busy, wall = s["busy_s"], s["wall_s"]
        assert abs(s["utilization"] - busy / (wall * s["workers"])) < 1e-9
        if pd[HOST]["workers"] == 4:
            assert pd[HOST]["utilization"] < 0.5
    finally:
        ex.shutdown()


def test_stress_wide_random_dag(executor):
    random.seed(7)
    tf = Taskflow()
    lock = threading.Lock()
    count = {"n": 0}

    def bump():
        with lock:
            count["n"] += 1

    layers = []
    for _ in range(10):
        layer = [tf.static(bump) for _ in range(100)]
        if layers:
            for t in layer:
                t.succeed(*random.sample(layers[-1], 3))
        layers.append(layer)
    executor.run(tf).wait()
    assert count["n"] == 1000


def test_cancellation(executor):
    started = threading.Event()
    release = threading.Event()
    ran_after = []
    tf = Taskflow()

    def first():
        started.set()
        release.wait(5)

    a = tf.static(first)
    b = tf.static(lambda: ran_after.append(1))
    a.precede(b)
    topo = executor.run(tf)
    started.wait(5)
    topo.cancel()
    release.set()
    topo.event.wait(5)
    assert ran_after == []
