"""Tensor-parallel sharded serving (docs/sharded_serving.md).

Three layers of defence:

* in-process unit tests for the mesh-divisibility gates and the typed
  refusal — no devices needed;
* a subprocess with 4 virtual CPU devices asserting the spec rules on a
  REAL mesh plus the no-accidental-gather invariant on the lowered
  decode-chunk HLO (zero all-reduces; every all-gather far below the
  per-device pool shard — the pool must never be reassembled);
* a subprocess running the full bit-exactness matrix: 2- and 4-way
  meshes x sync/async decode x prefix cache on/off, with chunked prefill
  and block growth exercised, against the single-device oracle.
"""
import dataclasses
import os
import subprocess
import sys

import pytest

from repro.configs import get_config
from repro.distributed.sharding import (MeshDivisibilityError,
                                        serve_attn_sharded,
                                        serve_mlp_sharded,
                                        validate_serve_mesh)


def _smoke():
    return get_config("stablelm-1.6b").smoke()


def test_serve_attn_sharded_gates():
    cfg = _smoke()  # KV=2, H=4, d_model=64
    assert serve_attn_sharded(cfg, 2)
    assert not serve_attn_sharded(cfg, 4)      # 4 does not divide KV=2
    assert not serve_attn_sharded(cfg, 1)      # single device: no TP
    ssm = dataclasses.replace(cfg, ssm=True)
    assert not serve_attn_sharded(ssm, 2)      # SSM serves replicated
    wide = dataclasses.replace(cfg, num_heads=8, num_kv_heads=4)
    assert serve_attn_sharded(wide, 4)


def test_serve_mlp_sharded_gates():
    cfg = _smoke()  # d_ff=96, d_model=64
    assert serve_mlp_sharded(cfg, 2)
    assert not serve_mlp_sharded(cfg, 64)      # 64 ∤ d_ff=96
    assert not serve_mlp_sharded(dataclasses.replace(cfg, ssm=True), 2)


def test_validate_serve_mesh_typed_error():
    cfg = _smoke()
    validate_serve_mesh(cfg, 1)                # trivial axis: fine
    validate_serve_mesh(cfg, 2)                # divides: fine
    with pytest.raises(MeshDivisibilityError) as ei:
        validate_serve_mesh(cfg, 4)
    assert "num_kv_heads=2" in str(ei.value)
    # typed subclass of ValueError so callers can catch broadly
    assert isinstance(ei.value, ValueError)
    # SSM/hybrid architectures serve replicated on any axis size
    validate_serve_mesh(dataclasses.replace(cfg, ssm=True), 4)


# ---------------------------------------------------------------- subprocess
# spec rules on a real 4-way mesh + the no-accidental-gather HLO invariant
SPECS_HLO_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.pop("REPRO_MESH_MODEL", None)
import dataclasses
import json
import jax
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.distributed.hlo_analysis import analyze_hlo
from repro.distributed.sharding import (serve_param_specs, serve_pool_spec,
                                        serve_kv_cache_spec)
from repro.launch.mesh import make_ctx, small_mesh
from repro.models import lm
from repro.serve.engine import ServeEngine

cfg = dataclasses.replace(get_config("stablelm-1.6b").smoke(),
                          num_heads=8, num_kv_heads=4)
ctx = make_ctx(small_mesh(data=1, model=4))

# ---- spec rules: projections shard their LAST dim; everything else
# (embed, lm_head, norms) is replicated so per-shard compute is bit-exact
shapes = jax.eval_shape(lambda k: lm.init_params(cfg, k),
                        jax.random.PRNGKey(0))
specs = serve_param_specs(cfg, shapes, ctx)
flat = jax.tree_util.tree_flatten_with_path(
    specs, is_leaf=lambda x: isinstance(x, P))[0]
by_path = {"/".join(str(getattr(k, "key", k)) for k in path): spec
           for path, spec in flat}
for name in ("wq", "wk", "wv", "wo", "wi", "wg", "wd"):
    spec = [v for k, v in by_path.items()
            if k.startswith("blocks") and k.endswith(name)][0]
    assert spec[-1] == "model" and all(s is None for s in spec[:-1]), \
        (name, spec)
for name in ("embed", "lm_head"):
    spec = [v for k, v in by_path.items() if k.endswith(name)][0]
    assert all(s is None for s in spec), (name, spec)
assert serve_pool_spec(cfg, ctx) == P(None, None, None, "model", None,
                                      None)
assert serve_kv_cache_spec(cfg, ctx) == P(None, None, "model", None, None)

# ---- lowered decode-chunk HLO: zero all-reduces, and no all-gather whose
# single largest operand/result approaches the per-device pool shard (the
# pool is (L, 2, N, KV/4, bs, hd) per device and must NEVER be gathered)
params = lm.init_params(cfg, jax.random.PRNGKey(0))
eng = ServeEngine(cfg, params, ctx=ctx, decode_chunk=4, max_batch=4,
                  kv_blocks=48, block_size=8, max_admit=2)
shard_bytes = eng._pkv.addressable_shards[0].data.nbytes
hlo = eng._decode_paged.lower(eng.params, eng._pkv, eng._tables_dev,
                              *eng._carry, n=4).compile().as_text()
cost = analyze_hlo(hlo)
eng.close()
assert cost.collective_counts["all-reduce"] == 0, cost.collective_counts
assert cost.collective_counts["all-gather"] > 0, \
    "TP decode must reassemble activations via all-gather"
biggest = cost.collective_max_bytes["all-gather"]
assert biggest < shard_bytes / 2, (biggest, shard_bytes)
print(json.dumps({"ok": True, "pool_shard_bytes": int(shard_bytes),
                  "ag_count": cost.collective_counts["all-gather"],
                  "ag_max_bytes": biggest}))
"""

# full parity matrix vs the single-device oracle
PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.pop("REPRO_MESH_MODEL", None)
import dataclasses
import jax
import numpy as np
from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeEngine
from repro.launch.mesh import make_ctx, small_mesh

cfg = dataclasses.replace(get_config("stablelm-1.6b").smoke(),
                          num_heads=8, num_kv_heads=4)
params = lm.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
# lengths straddle the prefill window (16) and block size (8): 41 streams
# across multiple chunked-prefill windows, the short ones grow blocks
prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
           for n in (23, 5, 9, 41, 17, 21)]
# the last two share prompt 0's first two blocks (16 tokens); with only 4
# seats they are admitted after it retires and registers its prefix, so
# prefix runs exercise real cache hits + CoW forks
prompts[4] = np.concatenate([prompts[0][:16], prompts[4][16:]])
prompts[5] = np.concatenate([prompts[0][:16], prompts[5][16:]])
kw = dict(decode_chunk=4, max_batch=4, kv_blocks=48, block_size=8,
          max_admit=2)

def run(ctx=None, async_decode=False, prefix=False):
    with ServeEngine(cfg, params, ctx=ctx, async_decode=async_decode,
                     prefix_cache=prefix, **kw) as eng:
        outs = eng.generate(prompts, max_new=12)
        stats = dict(eng.stats)
    return outs, stats

base, bstats = run()
assert bstats["grown_blocks"] > 0 and bstats["prefill_windows"] > 0, bstats
for mp in (2, 4):
    ctx = make_ctx(small_mesh(data=1, model=mp))
    for async_decode in (False, True):
        for prefix in (False, True):
            outs, st = run(ctx, async_decode, prefix)
            for i, (a, b) in enumerate(zip(base, outs)):
                assert np.array_equal(a, b), \
                    (mp, async_decode, prefix, i, a.tolist(), b.tolist())
            if prefix:
                assert st["prefix_hits"] > 0, (mp, async_decode, st)
            print(f"mp={mp} async={async_decode} prefix={prefix}: exact")

# per-device pool footprint shrinks by the mesh factor
ctx = make_ctx(small_mesh(data=1, model=4))
eng = ServeEngine(cfg, params, ctx=ctx, **kw)
full = eng._pkv.nbytes
shard = eng._pkv.addressable_shards[0].data.nbytes
assert shard * 4 == full, (full, shard)
eng.close()

# env-driven mesh: REPRO_MESH_MODEL clamps to the largest usable divisor
os.environ["REPRO_MESH_MODEL"] = "4"
cfg2 = get_config("stablelm-1.6b").smoke()   # KV=2: 4 clamps to 2
params2 = lm.init_params(cfg2, jax.random.PRNGKey(0))
eng = ServeEngine(cfg2, params2, **kw)
assert eng._tp == 2, eng._tp
eng.close()
del os.environ["REPRO_MESH_MODEL"]

# an EXPLICIT indivisible mesh is refused with the typed error
from repro.distributed.sharding import MeshDivisibilityError
try:
    ServeEngine(cfg2, params2, ctx=make_ctx(small_mesh(data=1, model=4)),
                **kw)
    raise AssertionError("expected MeshDivisibilityError")
except MeshDivisibilityError:
    pass
print("PARITY OK")
"""


def _run_sub(script: str, timeout: float):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_sharded_decode_hlo_has_no_pool_gather():
    r = _run_sub(SPECS_HLO_SCRIPT, 600)
    assert r.returncode == 0, r.stderr[-3000:]
    import json
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["ag_max_bytes"] < out["pool_shard_bytes"] / 2


@pytest.mark.slow
def test_mesh_serving_bit_exact_vs_single_device():
    r = _run_sub(PARITY_SCRIPT, 900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert r.stdout.strip().splitlines()[-1] == "PARITY OK"
