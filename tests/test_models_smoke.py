"""Per-architecture smoke tests (REDUCED same-family configs): one forward
/ train step on CPU asserting output shapes + no NaNs, plus
prefill->decode_step consistency against the teacher-forced forward."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import lm

ARCH_IDS = list(ARCHS)


def _batch(cfg, B=2, S=32, seed=1):
    tokens = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend != "none":
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (B, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = lm.forward(cfg, params, batch["tokens"],
                             batch.get("frontend_embeds"))
    F = cfg.frontend_tokens if cfg.frontend != "none" else 0
    assert logits.shape == (2, 32 + F, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    cfg = get_config(arch).smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = lm.loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: lm.loss_fn(cfg, p, batch)[0])(params)
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree_util.tree_leaves(grads))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = _batch(cfg, B, S)
    fe = batch.get("frontend_embeds")
    logits_full, _ = lm.forward(cfg, params, batch["tokens"], fe)
    lp, cache = lm.prefill(cfg, params, batch["tokens"][:, :S - 1],
                           max_len=S + 4, frontend_embeds=fe)
    F = cfg.frontend_tokens if cfg.frontend != "none" else 0
    ref = logits_full[:, F + S - 2]
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    err = float(jnp.max(jnp.abs(lp - ref))) / scale
    assert err < 2e-2, f"prefill mismatch {err}"
    # one decode step advances the cache; compare in probability space
    # (raw logits of an UNTRAINED model are ~0.1-scale, so max-abs relative
    # error is dominated by bf16 noise; the distribution is the semantics)
    ld, cache2 = lm.decode_step(cfg, params, cache,
                                batch["tokens"][:, S - 1])
    ref2 = logits_full[:, F + S - 1]
    p1 = jax.nn.softmax(ld[:, :cfg.vocab_size], axis=-1)
    p2 = jax.nn.softmax(ref2[:, :cfg.vocab_size], axis=-1)
    perr = float(jnp.max(jnp.abs(p1 - p2)))
    assert perr < 5e-3, f"decode distribution mismatch {perr}"
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


def test_param_counts_match_published():
    expect = {
        "qwen2.5-32b": 32.8, "stablelm-1.6b": 1.6, "qwen3-14b": 14.8,
        "mistral-nemo-12b": 12.2, "qwen2-moe-a2.7b": 14.3,
        "arctic-480b": 477, "musicgen-large": 2.4, "falcon-mamba-7b": 7.3,
        "zamba2-1.2b": 1.2, "internvl2-1b": 0.5,
    }
    for arch, want in expect.items():
        cfg = get_config(arch)
        got = cfg.param_count() / 1e9
        assert abs(got - want) / want < 0.12, (arch, got, want)


def test_moe_active_params():
    cfg = get_config("qwen2-moe-a2.7b")
    assert cfg.active_param_count() / 1e9 == pytest.approx(2.7, rel=0.05)
    arctic = get_config("arctic-480b")
    assert arctic.active_param_count() / 1e9 == pytest.approx(15.6, rel=0.1)
