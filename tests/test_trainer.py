import os

import jax
import pytest

from repro.configs import get_config
from repro.optim.adamw import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def test_trainer_runs_and_checkpoints(tmp_path):
    cfg = get_config("internvl2-1b").smoke()
    tc = TrainerConfig(total_steps=6, ckpt_every=3, log_every=2,
                       microbatches=1)
    tr = Trainer(cfg, tc, batch=2, seq_len=32,
                 opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=6),
                 ckpt_dir=str(tmp_path / "ckpt"))
    out = tr.run()
    assert out["state"]["step"] == 6
    assert out["restarts"] == 0
    assert all(h["loss"] == h["loss"] for h in out["history"])  # no NaN
    assert tr.ckpt.latest_step() == 6


def test_trainer_restarts_from_checkpoint_after_failure(tmp_path):
    cfg = get_config("stablelm-1.6b").smoke()
    tc = TrainerConfig(total_steps=10, ckpt_every=4, log_every=1,
                       fail_at_step=6, max_restarts=2, microbatches=2)
    tr = Trainer(cfg, tc, batch=4, seq_len=32,
                 opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=10),
                 ckpt_dir=str(tmp_path / "ckpt"))
    out = tr.run()
    assert out["restarts"] == 1
    assert out["state"]["step"] == 10
    steps = [h["step"] for h in out["history"]]
    assert 5 in steps and steps.count(5) >= 2  # 5 re-ran post-restore(4)


def test_trainer_resumes_across_runs(tmp_path):
    cfg = get_config("stablelm-1.6b").smoke()
    d = str(tmp_path / "ckpt")
    tc1 = TrainerConfig(total_steps=4, ckpt_every=2, log_every=1)
    Trainer(cfg, tc1, batch=2, seq_len=32, ckpt_dir=d).run()
    tc2 = TrainerConfig(total_steps=8, ckpt_every=2, log_every=1)
    out = Trainer(cfg, tc2, batch=2, seq_len=32, ckpt_dir=d).run()
    # second run resumed at 4 (no step <4 logged)
    assert min(h["step"] for h in out["history"]) >= 4
    assert out["state"]["step"] == 8


def test_trainer_fails_without_checkpointing():
    cfg = get_config("stablelm-1.6b").smoke()
    tc = TrainerConfig(total_steps=5, fail_at_step=2, max_restarts=2)
    tr = Trainer(cfg, tc, batch=2, seq_len=32, ckpt_dir=None)
    from repro.core import TaskError
    with pytest.raises(TaskError):
        tr.run()
