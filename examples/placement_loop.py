"""VLSI-placement-style optimization loop (paper §5.4 analogue): an
iterative matching/refinement algorithm with a data-dependent convergence
condition, expressed as ONE cyclic TDG — device phase (gradient-ish
refinement of cell coordinates) + host phase (overlap scoring) + condition
task deciding convergence. No unrolling; the same 5 tasks run any number
of iterations.

    PYTHONPATH=src python examples/placement_loop.py
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ACCEL, Executor, HOST, Taskflow


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", type=int, default=4096)
    ap.add_argument("--nets", type=int, default=8192)
    ap.add_argument("--tol", type=float, default=1e-3)
    ap.add_argument("--max-iters", type=int, default=100)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    pos = jnp.asarray(rng.random((args.cells, 2)).astype(np.float32))
    nets = jnp.asarray(rng.integers(0, args.cells,
                                    size=(args.nets, 2)).astype(np.int32))

    @jax.jit
    def wirelength(p):
        a, b = p[nets[:, 0]], p[nets[:, 1]]
        return jnp.sum(jnp.abs(a - b))

    @jax.jit
    def refine(p):
        # one smoothed-gradient step on the quadratic wirelength proxy
        g = jax.grad(lambda q: jnp.sum((q[nets[:, 0]] - q[nets[:, 1]])**2))(p)
        return jnp.clip(p - 0.002 * g, 0.0, 1.0)

    state = {"pos": pos, "wl": float(wirelength(pos)), "it": 0,
             "history": [float(wirelength(pos))]}

    ex = Executor(domains={HOST: 2, ACCEL: 1})
    tf = Taskflow("placement")

    init = tf.static(lambda: print(f"initial wirelength "
                                   f"{state['wl']:.1f}"))

    def device_refine():
        state["pos"] = refine(state["pos"])

    t_refine = tf.static(device_refine, name="refine", domain=ACCEL)

    def score() -> int:
        wl = float(wirelength(state["pos"]))
        rel = (state["wl"] - wl) / max(state["wl"], 1e-9)
        state["wl"] = wl
        state["it"] += 1
        state["history"].append(wl)
        converged = rel < args.tol or state["it"] >= args.max_iters
        return 1 if converged else 0

    t_cond = tf.condition(score, name="converged?")
    t_done = tf.static(lambda: None, name="done")

    init.precede(t_refine)
    t_refine.precede(t_cond)
    t_cond.precede(t_refine, t_done)    # 0 -> iterate, 1 -> stop

    ex.run(tf).wait()
    ex.shutdown()
    h = state["history"]
    print(f"converged after {state['it']} iterations "
          f"(graph has {tf.num_tasks()} tasks, constant for any count)")
    print(f"wirelength {h[0]:.1f} -> {h[-1]:.1f} "
          f"({100 * (1 - h[-1]/h[0]):.1f}% reduction)")


if __name__ == "__main__":
    main()
