"""Quickstart: the five task types of the paper on one page.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import ACCEL, DeviceFlow, Executor, HOST, Taskflow

# -- Listing 1: static tasking -------------------------------------------------
executor = Executor(domains={HOST: 4, ACCEL: 1})
taskflow = Taskflow("quickstart")

A, B, C, D = taskflow.emplace(
    lambda: print("Task A"),
    lambda: print("Task B"),
    lambda: print("Task C"),
    lambda: print("Task D"),
)
A.precede(B, C)     # A runs before B and C
D.succeed(B, C)     # D runs after  B and C
executor.run(taskflow).wait()

# -- Listing 2: dynamic tasking (subflow) -------------------------------------
tf2 = Taskflow()
A2 = tf2.static(lambda: print("A"))


def make_subflow(sf):
    print("B spawns B1,B2,B3")
    b1 = sf.static(lambda: print("  B1"))
    b2 = sf.static(lambda: print("  B2"))
    b3 = sf.static(lambda: print("  B3 (joins B1,B2)"))
    b3.succeed(b1, b2)


B2 = tf2.dynamic(make_subflow)
C2 = tf2.static(lambda: print("C"))
D2 = tf2.static(lambda: print("D (after subflow joined)"))
A2.precede(B2, C2)
D2.succeed(B2, C2)
executor.run(tf2).wait()

# -- Listing 3: composable tasking ---------------------------------------------
inner = Taskflow("inner")
ia = inner.static(lambda: print("inner A"))
ib = inner.static(lambda: print("inner B"))
ia.precede(ib)
outer = Taskflow("outer")
c = outer.static(lambda: print("outer C"))
mod = outer.composed_of(inner)
d = outer.static(lambda: print("outer D"))
c.precede(mod)
mod.precede(d)
executor.run(outer).wait()

# -- Listing 4: conditional tasking (cycles!) ----------------------------------
tf4 = Taskflow()
state = {"n": 0}
init = tf4.static(lambda: print("init"))


def coin() -> int:
    state["n"] += 1
    print(f"  flip #{state['n']}")
    return 1 if state["n"] >= 3 else 0   # 0 -> loop back, 1 -> continue


cond = tf4.condition(coin)
stop = tf4.static(lambda: print("stop"))
init.precede(cond)
cond.precede(cond, stop)   # successor 0 is itself: a cycle, not a DAG
executor.run(tf4).wait()

# -- Listing 5: device tasking (DeviceFlow = cudaFlow analogue) -----------------
tf5 = Taskflow()


def saxpy(df: DeviceFlow):
    import jax.numpy as jnp
    n = 1 << 16
    df.copy("x", np.ones(n, np.float32))
    df.copy("y", np.full(n, 2.0, np.float32))
    df.kernel(lambda x, y: 2.0 * x + y, ["x", "y"], ["z"])  # one XLA launch
    df.fetch("z")
    df._result_holder = df     # keep a handle for the check below
    tf5._df = df


dev = tf5.device(saxpy)
check = tf5.static(lambda: print(
    "saxpy ok:", bool((tf5._df.result("z") == 4.0).all())))
dev.precede(check)
executor.run(tf5).wait()

executor.shutdown()
print("quickstart complete")
