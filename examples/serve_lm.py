"""Continuous-batching serve demo.

One RESIDENT admit->prefill->decode->complete pipeline serves every request
for the life of the engine: ``submit()`` enqueues a prompt and returns a
future; the admit stage pulls length-bucketed groups from the queue at
chunk boundaries; decode advances ALL running sequences one compiled chunk
per cycle (N tokens per XLA launch — the cudaFlow single-launch effect);
finished sequences retire individually without draining the pipeline. While
request A is mid-decode, request B's prefill runs in the pipeline's prefill
stage — the overlap continuous batching is about.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-14b --batch 8
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--decode-chunk", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, decode_chunk=args.decode_chunk,
                      record_stages=True)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=args.prompt_len).astype(np.int32)
               for _ in range(args.batch)]
    # warm-up compiles the paged chunk program + the prefill shapes of the
    # admission group sizes the timed bursts will form
    eng.generate(prompts[:1] * len(prompts), max_new=args.decode_chunk + 1)

    # one burst through the resident pipeline (generate() is just
    # submit-all + gather: the compatibility shim over the request queue)
    t0 = time.time()
    outs = eng.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    total = args.batch * args.max_new
    print(f"{cfg.name}: {total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s) "
          f"via the resident pipeline "
          f"({eng.stats['decode_cycles']} chunked decode launches)")
    print("first sample:", outs[0][:24].tolist())

    # mixed prompt lengths: chunked prefill makes per-window shapes uniform,
    # so DIFFERENT lengths ride one admission group / one prefill launch and
    # share the decode batch — request B prefills while request A decodes,
    # then both advance in one chunk
    mixed = prompts[: args.batch // 2] + [
        rng.integers(0, cfg.vocab_size,
                     size=args.prompt_len // 2).astype(np.int32)
        for _ in range(args.batch - args.batch // 2)]
    before = dict(eng.stats)          # stats are engine-lifetime cumulative
    n_events = len(eng.stage_log)
    t0 = time.time()
    outs = eng.generate(mixed, max_new=args.max_new)
    kinds = [s for s, _, _, _ in eng.stage_log[n_events:]]
    print(f"mixed-length ({args.prompt_len} and {args.prompt_len//2}): "
          f"{total} tokens in {time.time()-t0:.2f}s; "
          f"{eng.stats['admitted'] - before['admitted']} admissions over "
          f"{eng.stats['prefills'] - before['prefills']} prefill launches, "
          f"{eng.stats['retired'] - before['retired']} individual "
          f"retirements, {kinds.count('pump')} pump cycles")

    # mid-stream submission: A decodes for a while, B joins halfway through
    a = eng.submit(prompts[0], max_new=args.max_new)
    time.sleep(0.05)
    b = eng.submit(prompts[1][: args.prompt_len // 2], max_new=8)
    ra, rb = eng.result(a), eng.result(b)
    print(f"mid-stream join: A got {ra.shape[0]} tokens, B got "
          f"{rb.shape[0]} tokens from the same pipeline run "
          f"(admit parks: {eng.stats['admit_parks']})")
    eng.close()


if __name__ == "__main__":
    main()
