"""Batched serving demo: compiled prefill + chunked decode (N tokens per
XLA launch — the cudaFlow single-launch effect), driven through the
4-stage generation pipeline (admit -> prefill -> decode -> complete) so
different prompt-length groups overlap prefill and decode.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-14b --batch 8
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--decode-chunk", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, decode_chunk=args.decode_chunk)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=args.prompt_len).astype(np.int32)
               for _ in range(args.batch)]
    # warm-up compiles prefill + decode-chunk programs
    eng.generate(prompts[:1] * len(prompts), max_new=args.decode_chunk + 1)

    t0 = time.time()
    outs = eng.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    total = args.batch * args.max_new
    launches = 1 + (args.max_new - 1 + args.decode_chunk - 1) \
        // args.decode_chunk
    print(f"{cfg.name}: {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s) using ~{launches} device launches "
          f"(chunked decode)")
    print("first sample:", outs[0][:24].tolist())

    # mixed prompt lengths: groups pipeline through prefill/decode stages
    mixed = prompts[: args.batch // 2] + [
        rng.integers(0, cfg.vocab_size,
                     size=args.prompt_len // 2).astype(np.int32)
        for _ in range(args.batch - args.batch // 2)]
    t0 = time.time()
    outs = eng.generate(mixed, max_new=args.max_new)
    print(f"mixed-length ({args.prompt_len} and {args.prompt_len//2}): "
          f"{total} tokens in {time.time()-t0:.2f}s, "
          f"{len(set(len(p) for p in mixed))} groups pipelined")
    eng.close()


if __name__ == "__main__":
    main()
