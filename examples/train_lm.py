"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps with the trainer-as-taskflow (prefetch / device-step / async
checkpoint / conditional loop), then greedy-decode from the trained model.

CPU-friendly default is a scaled-down run; pass --steps/--preset to grow.

    PYTHONPATH=src python examples/train_lm.py --steps 60
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import tempfile

import numpy as np

from repro.configs import get_config
from repro.launch.train import build_cfg
from repro.optim.adamw import OptConfig
from repro.serve.engine import ServeEngine
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=0)
    args = ap.parse_args()

    cfg, batch, seq = build_cfg(args.arch, args.preset)
    batch = args.batch or batch
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params), "
          f"batch={batch}, seq={seq}, steps={args.steps}")

    with tempfile.TemporaryDirectory() as ckpt:
        tc = TrainerConfig(total_steps=args.steps,
                           ckpt_every=max(20, args.steps // 4),
                           log_every=max(1, args.steps // 12))
        opt = OptConfig(lr=3e-3 if args.preset == "smoke" else 6e-4,
                        warmup_steps=max(5, args.steps // 10),
                        total_steps=args.steps, weight_decay=0.0)
        tr = Trainer(cfg, tc, batch=batch, seq_len=seq, opt=opt,
                     ckpt_dir=ckpt)
        out = tr.run()
        hist = out["history"]
        for h in hist:
            print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
                  f"lr {h['lr']:.2e}")
        print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
              f"(uniform floor ~{np.log(cfg.vocab_size):.2f})")

        eng = ServeEngine(cfg, out["state"]["params"], decode_chunk=8)
        prompt = np.arange(1, 17, dtype=np.int32)
        gen = eng.generate([prompt], max_new=16)[0]
        print("sample continuation:", gen.tolist())


if __name__ == "__main__":
    main()
