"""The paper's flagship workload (§5.3): Large Sparse DNN inference as a
conditional task graph — condition tasks drive the data-dependent pass
loop, and each pass offloads ONE captured device graph (all layer blocks)
in a single launch.

    PYTHONPATH=src python examples/lsdnn_inference.py --layers 48
"""
import argparse

from benchmarks.fig13_lsdnn import bench


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--layers", type=int, default=48)
    ap.add_argument("--neurons", type=int, default=512)
    ap.add_argument("--passes", type=int, default=3)
    args = ap.parse_args()
    for name, val, derived in bench(layers=args.layers,
                                    neurons=args.neurons,
                                    passes=args.passes):
        print(f"{name:36s} {val:14.3f}  {derived}")


if __name__ == "__main__":
    main()
