# Observability overhead gate (ROADMAP production-serve goal, not a paper
# figure): instrumentation must be effectively free.
"""Serve throughput with observability ON vs OFF, gated to a budget.

The serve engine's instrumentation discipline (``repro.obs``: cached
metric handles, ring-buffer span appends, single ``None`` checks on the
disabled path) only holds if it is *measured*: this gate drives ONE
engine over an identical saturated decode workload with observability
enabled and disabled and asserts the enabled-path tokens/sec stays within
a budget of the disabled path.

Methodology: repetitions are INTERLEAVED off/on and each mode is scored
by its BEST repetition (minimum wall time). Instrumentation cost is
deterministic work on every cycle, so it survives into the cleanest
repetition; CPU-quota throttling on a shared container does not (run-to-
run throughput here swings ±15%, far more than the budget — a mean or
median gate would be pure noise). Both modes run the SAME compiled
programs (``ServeEngine.set_obs`` rebinding at idle — no second jit
warm-up that would dwarf the effect being measured).

Budget: the ``REPRO_OBS_GATE_BUDGET`` env var (fraction, default 0.02 —
the local 2% budget; CI passes 0.05 for shared-runner slack).
"""
from __future__ import annotations

import os
import time
from typing import Iterator, Tuple


def _run(eng, prompts, max_new: int) -> float:
    for k in eng.stats:
        eng.stats[k] = 0
    if eng.obs is not None:
        eng.obs.reset()
    t0 = time.perf_counter()
    reqs = [eng.submit(p, max_new) for p in prompts]
    for r in reqs:
        eng.result(r, timeout=600.0)
    return time.perf_counter() - t0


def bench(quick: bool = False) -> Iterator[Tuple[str, str, str]]:
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import lm
    from repro.obs import Observability
    from repro.serve.engine import ServeEngine

    budget = float(os.environ.get("REPRO_OBS_GATE_BUDGET", "0.02"))
    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    chunk = 4
    n_req = 6
    max_new = 64 if quick else 128
    reps = 5 if quick else 8
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(n_req)]
    total_tokens = n_req * max_new
    obs = Observability()

    samples = {"off": [], "on": []}
    with ServeEngine(cfg, params, decode_chunk=chunk, max_batch=8,
                     kv_blocks=224, block_size=8, prefill_chunk=16,
                     max_seq_len=-(-(8 + max_new) // 8) * 8) as eng:
        # warm-up compiles every program both modes will run (identical:
        # obs never changes compiled shapes)
        _run(eng, prompts, max(2, chunk + 1))
        for _ in range(reps):
            for mode in ("off", "on"):
                eng.set_obs(obs if mode == "on" else None)
                dt = _run(eng, prompts, max_new)
                samples[mode].append(total_tokens / dt)
        eng.set_obs(None)
    # best-of (min wall time) per mode: deterministic per-cycle
    # instrumentation work survives into the cleanest repetition,
    # container contention does not
    off = float(np.max(samples["off"]))
    on = float(np.max(samples["on"]))
    ratio = on / off
    yield ("obs_gate_off_tok_per_s", f"{off:.1f}", f"best_of_{reps}")
    yield ("obs_gate_on_tok_per_s", f"{on:.1f}", f"{ratio:.3f}x_off")
    yield ("obs_gate_overhead_frac", f"{max(0.0, 1.0 - ratio):.4f}",
           f"budget_{budget:.2f}")
    if ratio < 1.0 - budget:
        raise AssertionError(
            f"observability overhead gate failed: enabled path at "
            f"{on:.1f} tok/s vs disabled {off:.1f} tok/s "
            f"({(1.0 - ratio) * 100:.1f}% > {budget * 100:.0f}% budget)")
    yield ("obs_gate", "ok", f"within_{budget * 100:.0f}pct")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    for name, val, derived in bench(quick=args.quick):
        print(f"{name},{val},{derived}")
