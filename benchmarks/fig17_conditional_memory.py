"""Paper Figure 17 (memory panel) / §5.3-5.4: conditional tasking keeps
memory FLAT as the iteration count grows, while DAG frameworks must
statically unroll.

Two levels, both measured:
* host TDG: task count + graph bytes of the cyclic conditional taskflow vs
  an unrolled DAG, across iteration counts;
* in-XLA (the TPU-native layer): HLO size + compile artifacts of a
  `jaxgraph` while-loop program vs the same loop fully unrolled.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from repro.core import STOP, JaxGraph, Taskflow


def _host_graph_bytes(tf: Taskflow) -> int:
    total = 0
    for n in tf._nodes:
        total += sys.getsizeof(n)
        total += sys.getsizeof(n.successors)
    return total


def bench(iters=(8, 64, 512)):
    rows = []
    for k in iters:
        # cyclic conditional: constant 3 tasks for ANY k
        tf = Taskflow()
        state = {"i": 0}
        body = tf.static(lambda: None)

        def cond(k=k, state=state) -> int:
            state["i"] += 1
            return 1 if state["i"] >= k else 0

        c = tf.condition(cond)
        stop = tf.static(lambda: None)
        body.precede(c)
        c.precede(body, stop)
        rows.append((f"fig17/host/cyclic_k{k}_tasks", tf.num_tasks(),
                     "constant"))
        rows.append((f"fig17/host/cyclic_k{k}_bytes", _host_graph_bytes(tf),
                     "constant"))

        # unrolled: k tasks
        tfu = Taskflow()
        prev = None
        for _ in range(k):
            t = tfu.static(lambda: None)
            if prev is not None:
                prev.precede(t)
            prev = t
        rows.append((f"fig17/host/unrolled_k{k}_tasks", tfu.num_tasks(),
                     "grows with k"))
        rows.append((f"fig17/host/unrolled_k{k}_bytes",
                     _host_graph_bytes(tfu), "grows with k"))

    # in-XLA comparison at fixed k
    k = 256
    x = jnp.ones((256, 256), jnp.float32)

    g = JaxGraph()
    stp = g.task(lambda s: {"i": s["i"] + 1, "x": s["x"] @ s["x"] * 0.5})
    cnd = g.cond(lambda s: (jnp.where(s["i"] >= k, 1, 0), s))
    stp.precede(cnd)
    cnd.precede(stp, STOP)
    st = {"i": jnp.int32(0), "x": x}
    loop_hlo = jax.jit(g.lower()).lower(st).compile().as_text()

    def unrolled(s):
        xx = s["x"]
        for _ in range(k):
            xx = xx @ xx * 0.5
        return xx

    unrolled_hlo = jax.jit(unrolled).lower(st).compile().as_text()
    rows += [
        (f"fig17/xla/while_hlo_bytes_k{k}", len(loop_hlo),
         "conditional in-graph"),
        (f"fig17/xla/unrolled_hlo_bytes_k{k}", len(unrolled_hlo),
         "static unroll"),
        (f"fig17/xla/hlo_ratio", len(unrolled_hlo) / len(loop_hlo),
         "unrolled / conditional"),
    ]
    return rows


if __name__ == "__main__":
    for name, val, derived in bench():
        print(f"{name},{val:.1f},{derived}")
