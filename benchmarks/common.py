"""Shared benchmark scaffolding: baseline schedulers the paper compares
against, reimplemented as *paradigms* (the C++ frameworks themselves are not
available in-process):

* ``sequential``   — topological order, one thread (lower bound on overhead)
* ``levelized``    — level-by-level with barriers, the paper's description
                     of the OpenMP baseline ("levelize the graph and
                     propagate computations level by level")
* ``futures``      — concurrent.futures.ThreadPoolExecutor DAG scheduler
                     (an industrial work-queue runtime without work stealing
                     or adaptive sleep)
* ``taskflow``     — our reproduction of the paper's work-stealing executor

All consume the same graph description: ``nodes = [callable, ...]``,
``edges = [(u, v), ...]``.

NOTE: this container exposes ONE CPU core, so wall-clock *speedups* between
schedulers cannot materialize; what remains comparable (and what the paper's
Tables 1-2 measure) are per-task overheads, scheduling efficiency counters
(steals, sleeps, utilization), memory, and graph-size scaling.
"""
from __future__ import annotations

import gc
import resource
import time
from collections import defaultdict, deque
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Sequence, Tuple

from repro.core import Executor, Profiler, Taskflow

Edge = Tuple[int, int]


def peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run_sequential(nodes: Sequence[Callable], edges: Sequence[Edge]) -> float:
    order = topo_order(len(nodes), edges)
    t0 = time.perf_counter()
    for i in order:
        nodes[i]()
    return time.perf_counter() - t0


def topo_order(n: int, edges: Sequence[Edge]) -> List[int]:
    succ = defaultdict(list)
    indeg = [0] * n
    for u, v in edges:
        succ[u].append(v)
        indeg[v] += 1
    q = deque(i for i in range(n) if indeg[i] == 0)
    order = []
    while q:
        u = q.popleft()
        order.append(u)
        for v in succ[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                q.append(v)
    return order


def levels_of(n: int, edges: Sequence[Edge]) -> List[List[int]]:
    succ = defaultdict(list)
    indeg = [0] * n
    for u, v in edges:
        succ[u].append(v)
        indeg[v] += 1
    level = [0] * n
    for u in topo_order(n, edges):
        for v in succ[u]:
            level[v] = max(level[v], level[u] + 1)
    out: Dict[int, List[int]] = defaultdict(list)
    for i, l in enumerate(level):
        out[l].append(i)
    return [out[l] for l in sorted(out)]


def run_levelized(nodes: Sequence[Callable], edges: Sequence[Edge],
                  workers: int = 4) -> float:
    """OpenMP-paradigm baseline: barrier after every level."""
    lv = levels_of(len(nodes), edges)
    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=workers) as pool:
        for level in lv:
            list(pool.map(lambda i: nodes[i](), level))
    return time.perf_counter() - t0


def run_futures(nodes: Sequence[Callable], edges: Sequence[Edge],
                workers: int = 4) -> float:
    """Dependency-counting scheduler on a plain thread pool."""
    import threading
    succ = defaultdict(list)
    indeg = defaultdict(int)
    n = len(nodes)
    for u, v in edges:
        succ[u].append(v)
        indeg[v] += 1
    lock = threading.Lock()
    done = threading.Event()
    remaining = [n]
    pool = ThreadPoolExecutor(max_workers=workers)

    def submit(i):
        pool.submit(run, i)

    def run(i):
        nodes[i]()
        ready = []
        with lock:
            remaining[0] -= 1
            if remaining[0] == 0:
                done.set()
            for v in succ[i]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    ready.append(v)
        for v in ready:
            submit(v)

    t0 = time.perf_counter()
    for i in range(n):
        if indeg[i] == 0:
            submit(i)
    done.wait()
    dt = time.perf_counter() - t0
    pool.shutdown(wait=False)
    return dt


def run_taskflow(nodes: Sequence[Callable], edges: Sequence[Edge],
                 workers: int = 4, profile: bool = False):
    prof = Profiler() if profile else None
    ex = Executor(domains={"host": workers}, observer=prof)
    tf = Taskflow("bench")
    tasks = [tf.static(fn) for fn in nodes]
    for u, v in edges:
        tasks[u].precede(tasks[v])
    t0 = time.perf_counter()
    ex.run(tf).wait()
    dt = time.perf_counter() - t0
    ex.shutdown(wait=False)
    if profile:
        return dt, prof.summary()
    return dt


def random_layered_dag(n_tasks: int, width: int = 64, fan_in: int = 3,
                       seed: int = 0) -> Tuple[int, List[Edge]]:
    import random as _r
    rng = _r.Random(seed)
    edges: List[Edge] = []
    layers: List[List[int]] = []
    i = 0
    while i < n_tasks:
        w = min(width, n_tasks - i)
        layer = list(range(i, i + w))
        if layers:
            prev = layers[-1]
            for v in layer:
                for u in rng.sample(prev, min(fan_in, len(prev))):
                    edges.append((u, v))
        layers.append(layer)
        i += w
    return n_tasks, edges
