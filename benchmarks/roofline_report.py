"""Roofline table (§Roofline deliverable): post-processes the dry-run
records in results/dryrun.jsonl into the EXPERIMENTS.md table — the three
terms, dominant bottleneck, useful-flops fraction, fits-HBM flag, and a
kind-aware efficiency metric (decode cells are judged against mandatory
bytes: params + cache must stream from HBM each step).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def load(path="results/dryrun.jsonl", tag=""):
    seen = {}
    p = Path(path)
    if not p.exists():
        return {}
    for line in p.read_text().splitlines():
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        if r.get("ok") and r.get("tag", "") == tag:
            seen[(r["arch"], r["shape"], r["mesh"])] = r
    return seen


def decode_min_bytes(arch, shape, chips):
    """Mandatory per-step HBM traffic for decode: every (active) param +
    the whole KV cache / SSM state is read once."""
    cfg = get_config(arch)
    pbytes = cfg.active_param_count() * (2 if cfg.param_dtype == "bfloat16"
                                         else 4)
    return pbytes / chips  # cache bytes are in the record's argument bytes


def rows(path="results/dryrun.jsonl", tag=""):
    out = []
    for (a, s, m), r in sorted(load(path, tag).items()):
        rf = r["roofline"]
        rec = {
            "arch": a, "shape": s, "mesh": m,
            "peak_gb": r["memory"]["peak_bytes"] / 1e9,
            "fits": r["memory"]["fits_16gb"],
            "compute_s": rf["compute_s"],
            "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "dominant": rf["dominant"],
            "useful": rf["useful_flops_frac"],
            "frac": rf["roofline_frac"],
        }
        if r["kind"] == "decode":
            minb = decode_min_bytes(a, s, r["chips"]) \
                + r["memory"]["argument_bytes"] * 0.9
            rec["frac"] = min(1.0, (minb / HBM_BW)
                              / max(rf["memory_s"], rf["collective_s"],
                                    rf["compute_s"], 1e-12))
            rec["dominant"] += " (bw-bound)"
        out.append(rec)
    return out


def bench():
    rs = rows()
    out = []
    for r in rs:
        out.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                    r["frac"],
                    f"dom={r['dominant']} peak={r['peak_gb']:.1f}GB"))
    return out


def markdown(path="results/dryrun.jsonl", tag="") -> str:
    lines = ["| arch | shape | mesh | peak GB | fits | compute s | "
             "memory s | collective s | dominant | useful | frac |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows(path, tag):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['peak_gb']:.2f} | {'Y' if r['fits'] else 'N'} | "
            f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.3f} | {r['dominant']} | "
            f"{r['useful']:.2f} | {r['frac']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown())
