# SLO-aware overload bench (not a paper figure: the ROADMAP
# production-serve goal). Mixed-tier saturation against the resident
# engine: does tier-0 hold its tail TTFT while best-effort load sheds?
"""tier-0 tail TTFT under best-effort saturation, with overload control.

Two phases over seeded traces on ONE resident engine:

* ``uncontended`` — the tier-0 (SLO) trace alone: sparse Poisson
  arrivals of short prompts. Its TTFT p99 is the reference the SLO is
  measured against.
* ``contended``  — the identical tier-0 arrivals interleaved with a
  tier-1 best-effort FLOOD (near-simultaneous heavy-tailed lognormal
  prompts, short deadlines, a small tier-1 shed budget). Offered load
  far exceeds service rate, so the overload-control machinery has to
  do the work: queue-wait shedding (typed ``Overloaded`` at submit),
  queue-deadline expiry, tier-aware admission (``tier_targets``), and
  cost-model preemption that spares tier-0 residents.

Reported: tier-0 TTFT p50/p99 for both phases and the contended/
uncontended p99 ratio (the acceptance target is <= 2x — reported, not
asserted, because single-stream CPU smoke timing is noisy), plus the
overload-control counters (shed / expired / preempted) and the tier-1
completion breakdown. Every percentile is read back from the engine's
own per-tier ``serve.ttft_s.tier{N}`` registry histograms; nonzero
``shed``+``expired`` in the contended phase is what distinguishes
"survived by controlling load" from "survived because load was light".
"""
from __future__ import annotations

import time
from typing import Iterator, Tuple


def _mk_trace(rng, n: int, rate_hz: float, lens, max_new: int,
              priority: int, deadline_s):
    """Poisson arrivals: (t, prompt, max_new, priority, deadline) rows."""
    import numpy as np
    t, out = 0.0, []
    for i in range(n):
        t += rng.exponential(1.0 / rate_hz)
        size = int(lens[i % len(lens)]) if hasattr(lens, "__len__") \
            else int(lens)
        prompt = rng.integers(0, 500, size=size).astype(np.int32)
        out.append((t, prompt, max_new, priority, deadline_s))
    return out


def bench(quick: bool = False,
          trace_path: str = None) -> Iterator[Tuple[str, str, str]]:
    """trace_path: write the contended phase's Chrome trace JSON here."""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import lm
    from repro.obs import Observability
    from repro.serve.engine import ServeEngine
    from repro.serve.errors import ServeError

    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    chunk = 4 if quick else 8
    bs = 8
    n0 = 6 if quick else 12              # tier-0 (SLO) requests
    n1 = 60 if quick else 72             # tier-1 best-effort flood
    max_new0 = 8 if quick else 16
    # tier-1 decodes LONG: offered work (n1 x max_new1 / chunk cycles)
    # must exceed what the narrow batch can serve inside the arrival
    # window, or nothing queues and the overload controls never engage
    max_new1 = 64
    # tier-0 alone must NOT saturate (it is the reference). The flood
    # must, but it also has to ARRIVE across the congestion it creates:
    # shedding keys on observed queue waits (>=8 admissions before the
    # estimator arms), so a burst that lands entirely before the first
    # admission wave would never see a single Overloaded
    rate0 = 2.0
    # flood arrivals COMPRESSED (~1.5s window): the backlog must build
    # while later submits are still arriving, or every shed/expiry
    # opportunity has already passed by the time queue waits grow
    rate1 = 60.0
    # the deadline must be TIGHTER than the time a queued tier-1 request
    # actually waits under saturation, or expiry never fires and shedding
    # absorbs the whole overload (the two controls compete: shed rejects
    # at the door once the estimator arms, expiry reaps what slipped in
    # before it armed or decayed mid-decode)
    tier1_deadline = 0.15 if quick else 2.0
    # a NARROW engine (few resident rows) is what makes the smoke-scale
    # flood an overload: single-stream CPU service is otherwise fast
    # enough that the whole flood drains without ever queueing
    max_batch = 2

    rng = np.random.default_rng(0)
    lens0 = (8, 12) if quick else (12, 16, 24)
    cap = 32 if quick else 64
    raw = rng.lognormal(mean=np.log(12.0), sigma=0.8, size=n1)
    lens1 = np.clip((np.ceil(raw / 4) * 4).astype(int), 4, cap)

    t0_trace = _mk_trace(rng, n0, rate0, lens0, max_new0,
                         priority=0, deadline_s=None)
    t1_trace = _mk_trace(rng, n1, rate1, lens1, max_new1,
                         priority=1, deadline_s=tier1_deadline)
    merged = sorted(t0_trace + t1_trace, key=lambda r: r[0])

    max_len = max(len(p) for _, p, _, _, _ in merged)
    max_seq = -(-(max_len + max(max_new0, max_new1)) // bs) * bs
    prefill_chunk = 2 * bs
    distinct = sorted({len(p) for _, p, _, _, _ in merged})

    obs = Observability()
    with ServeEngine(cfg, params, decode_chunk=chunk, block_size=bs,
                     max_seq_len=max_seq, kv_blocks=48 if quick else 64,
                     max_batch=max_batch, max_admit=max_batch,
                     prefill_chunk=prefill_chunk,
                     tier_targets={1: 0.25},
                     # budget LOOSER than the deadline, so the shed gate's
                     # effective limit IS the deadline (min of the two): the
                     # estimator's lag then admits a cohort whose real waits
                     # overshoot the deadline (-> expiry) before the p90
                     # crosses it and the remaining tail sheds at the door
                     shed_budget_s={1: 0.3 if quick else 0.5},
                     obs=obs) as eng:
        # warm-up: one request per distinct pow2 prefill bucket, then one
        # saturating mixed burst for merge/growth/retire shapes (the
        # serve_continuous idiom)
        buckets = {1 << max(0, s - 1).bit_length(): s for s in distinct}
        for s in buckets.values():
            warm = [p for _, p, _, _, _ in merged if len(p) == s][:1]
            if warm:
                eng.generate(warm, max_new=chunk + 1)
        eng.generate([p for _, p, _, _, _ in merged], max_new=chunk + 1)

        def _run(trace):
            for k in eng.stats:
                eng.stats[k] = 0
            obs.reset()
            t_start = time.perf_counter()
            pending, submit_errs = [], 0
            for at, prompt, mn, prio, dl in trace:
                now = time.perf_counter() - t_start
                if now < at:
                    time.sleep(at - now)
                try:
                    pending.append(eng.submit(prompt, max_new=mn,
                                              priority=prio, deadline_s=dl))
                except ServeError:
                    submit_errs += 1       # Overloaded: shed at the door
            done, failed = 0, 0
            for r in pending:
                try:
                    eng.result(r, timeout=600.0)
                    done += 1
                except ServeError:
                    failed += 1            # expired / cancelled / preempted
            dt = time.perf_counter() - t_start
            h0 = obs.metrics.get("serve.ttft_s.tier0")
            ttft0 = h0.summary() if h0 is not None else None
            h1 = obs.metrics.get("serve.ttft_s.tier1")
            ttft1 = h1.summary() if h1 is not None else None
            return {"dt": dt, "ttft0": ttft0, "ttft1": ttft1,
                    "done": done, "failed": failed, "shed": submit_errs,
                    "stats": dict(eng.stats)}

        base = _run(t0_trace)              # uncontended reference
        cont = _run(merged)                # best-effort saturation
        if trace_path:
            obs.export(trace_path)

    b99 = base["ttft0"]["p99"]
    c99 = cont["ttft0"]["p99"]
    ratio = c99 / max(b99, 1e-9)
    st = cont["stats"]
    yield ("serve_slo_tier0_ttft_p99_ms", f"{c99*1e3:.0f}",
           f"{ratio:.2f}x_uncontended")
    yield ("serve_slo_tier0_ttft_p50_ms",
           f"{cont['ttft0']['p50']*1e3:.0f}",
           f"uncontended_{base['ttft0']['p50']*1e3:.0f}ms")
    yield ("serve_slo_uncontended_p99_ms", f"{b99*1e3:.0f}",
           f"count_{base['ttft0']['count']}")
    yield ("serve_slo_within_2x", str(ratio <= 2.0),
           "acceptance_target_reported_not_asserted")
    yield ("serve_slo_shed", str(st["shed"]),
           f"{cont['shed']}_submit_rejections")
    yield ("serve_slo_expired", str(st["expired"]),
           f"deadline_{tier1_deadline:.1f}s")
    yield ("serve_slo_preempted", str(st["preempted"]),
           f"{st['stalls']}_stalls")
    yield ("serve_slo_completed", str(cont["done"]),
           f"of_{n0 + n1}_offered_{cont['failed']}_failed_typed")
    if cont["ttft1"] is not None and cont["ttft1"]["count"]:
        yield ("serve_slo_tier1_ttft_p50_ms",
               f"{cont['ttft1']['p50']*1e3:.0f}",
               f"count_{cont['ttft1']['count']}")
    yield ("serve_slo_workload",
           f"{n0}slo_{n1}flood", f"contended_dt_{cont['dt']:.1f}s")
    if trace_path:
        yield ("serve_slo_trace_spans", str(len(obs.tracer)), trace_path)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the contended phase's Chrome trace-event "
                         "JSON here")
    args = ap.parse_args()
    for name, val, derived in bench(quick=args.quick,
                                    trace_path=args.trace):
        print(f"{name},{val},{derived}")
