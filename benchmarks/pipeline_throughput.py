"""Pipeline throughput (Pipeflow, arXiv:2202.00717 §5): scheduling tokens/sec
through the L-lines × S-stages task-parallel pipeline, vs the hand-rolled
sequential loop it replaces.

Three panels:

* ``micro``    — synthetic fixed-work stages; lines × stages scaling of the
                 pipeline scheduler against a plain host loop running the
                 same stage bodies (derived column = pipeline/loop ratio);
* ``prefetch`` — the data layer's 2-stage prefetch pipeline in executor mode
                 vs the manual ``produce_one`` drive (batches/sec);
* ``serve``    — LM tokens/sec of the pipelined 4-stage ``ServeEngine``
                 (mixed-length groups overlapping prefill/decode) vs a
                 hand-rolled group-serial loop over the same compiled fns.

NOTE: this container exposes ONE CPU core (see benchmarks/common.py), so the
ratios measure *scheduling overhead*, not parallel speedup; the lines×stages
scaling shape and the zero-dedicated-thread property are the point.
"""
from __future__ import annotations

import time

from repro.core import ACCEL, HOST, Executor
from repro.pipeline import DataPipe, DataPipeline, Pipe, PipeType, Pipeline


def _spin(n: int) -> int:
    acc = 0
    for i in range(n):
        acc += i * i
    return acc


def _micro_rows(quick: bool):
    ntokens = 200 if quick else 2_000
    work = 200 if quick else 1_000
    S = 4
    kinds = [PipeType.SERIAL] + [PipeType.PARALLEL, PipeType.SERIAL,
                                 PipeType.PARALLEL][:S - 1]

    # hand-rolled loop baseline: same stage bodies, one host thread
    t0 = time.perf_counter()
    for _ in range(ntokens):
        for _s in range(S):
            _spin(work)
    loop_dt = time.perf_counter() - t0
    loop_rate = ntokens / loop_dt
    yield "pipeline_micro_loop_tok_per_s", f"{loop_rate:.1f}", "baseline"

    for L in ((1, 4) if quick else (1, 2, 4, 8)):
        ex = Executor(domains={HOST: 4})
        budget = ntokens

        def mk(s):
            def stage(pf):
                if s == 0 and pf.token >= budget:
                    pf.stop()
                    return
                _spin(work)
            return stage

        pl = Pipeline(L, *[Pipe(kinds[s], mk(s), name=f"s{s}")
                           for s in range(S)])
        t0 = time.perf_counter()
        pl.run(ex).wait()
        dt = time.perf_counter() - t0
        ex.shutdown(wait=False)
        rate = pl.num_tokens / dt
        yield (f"pipeline_micro_L{L}S{S}_tok_per_s", f"{rate:.1f}",
               f"{rate/loop_rate:.2f}x_loop_defer={pl.num_deferrals}")


def _prefetch_rows(quick: bool):
    from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
    cfg = DataConfig(vocab_size=512, seq_len=64 if quick else 256,
                     global_batch=4 if quick else 16, seed=0)
    nbatches = 20 if quick else 100

    src = SyntheticLM(cfg)
    p = Prefetcher(src.batch_at, depth=4)
    t0 = time.perf_counter()
    got = 0
    while got < nbatches:
        p.produce_one()
        p.get(timeout=30)
        got += 1
    manual_dt = time.perf_counter() - t0
    yield ("prefetch_manual_batch_per_s", f"{nbatches/manual_dt:.1f}",
           "baseline")

    ex = Executor(domains={HOST: 4})
    src = SyntheticLM(cfg)
    p = Prefetcher(src.batch_at, depth=4, executor=ex)
    t0 = time.perf_counter()
    p.start()
    for _ in range(nbatches):
        p.get(timeout=30)
    pipe_dt = time.perf_counter() - t0
    p.stop()
    ex.shutdown(wait=False)
    yield ("prefetch_pipeline_batch_per_s", f"{nbatches/pipe_dt:.1f}",
           f"{manual_dt/pipe_dt:.2f}x_manual")


def _serve_rows(quick: bool):
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve.engine import ServeEngine

    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    max_new = 8 if quick else 32
    chunk = 4 if quick else 8
    rng = np.random.default_rng(0)
    lens = (8, 12) if quick else (16, 24, 32, 48)
    prompts = [rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
               for s in lens for _ in range(2)]
    total = len(prompts) * max_new

    with ServeEngine(cfg, params, decode_chunk=chunk) as eng:
        # this row isolates SCHEDULING overlap, so both arms must run the
        # SAME compiled programs: pin the per-call grouped pipeline (the
        # resident continuous engine is measured by benchmarks/
        # serve_continuous.py against its own baseline instead)
        eng._generate_grouped(prompts, max_new)  # warm-up: compile shapes
        t0 = time.perf_counter()
        outs = eng._generate_grouped(prompts, max_new)
        pipe_dt = time.perf_counter() - t0

        # hand-rolled baseline: the pre-pipeline host loop, group-serial,
        # over the SAME compiled programs
        t0 = time.perf_counter()
        import jax.numpy as jnp
        for s in lens:
            group = [p for p in prompts if len(p) == s]
            toks = np.stack(group)
            logits, cache = eng._prefill(eng.params, jnp.asarray(toks),
                                         None, max_len=s + max_new + 1)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            seqs = [np.asarray(cur)[:, None]]
            remaining = max_new - 1
            while remaining > 0:
                n = min(chunk, remaining)
                cache, ch = eng._decode_n(eng.params, cache, cur, n)
                seqs.append(np.asarray(ch))
                cur = ch[:, -1]
                remaining -= n
        loop_dt = time.perf_counter() - t0

    assert all(o is not None for o in outs)
    yield "serve_loop_tok_per_s", f"{total/loop_dt:.1f}", "baseline"
    yield ("serve_pipeline_tok_per_s", f"{total/pipe_dt:.1f}",
           f"{loop_dt/pipe_dt:.2f}x_loop_{len(lens)}groups")


def bench(quick: bool = False):
    rows = []
    for gen in (_micro_rows, _prefetch_rows, _serve_rows):
        rows.extend(gen(quick))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="seconds-scale smoke sizes (tier-1 environment)")
    args = ap.parse_args()
    for name, val, derived in bench(quick=args.quick):
        print(f"{name},{val},{derived}", flush=True)


if __name__ == "__main__":
    main()
