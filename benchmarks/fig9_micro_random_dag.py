"""Paper Figure 9: overall system performance on randomly generated DAGs
with a mix of host tasks and device (JAX) tasks, across graph sizes,
comparing the work-stealing executor against the sequential / levelized
(OpenMP-paradigm) / futures baselines. Also reports peak RSS (the paper's
memory panel) and scheduler-efficiency counters.
"""
from __future__ import annotations

import time

import numpy as np

from .common import (peak_rss_mb, random_layered_dag, run_futures,
                     run_levelized, run_sequential, run_taskflow)


def _mk_nodes(n):
    # paper micro-benchmark: each task does a small vector addition (1K)
    xs = np.ones(1024, np.float32)

    def work():
        (xs + xs).sum()

    return [work] * n


def bench(sizes=(1_000, 5_000, 20_000), workers: int = 4):
    rows = []
    for n in sizes:
        _, edges = random_layered_dag(n, width=max(32, n // 50))
        nodes = _mk_nodes(n)
        seq = run_sequential(nodes, edges)
        lvl = run_levelized(nodes, edges, workers)
        fut = run_futures(nodes, edges, workers)
        tfl, prof = run_taskflow(nodes, edges, workers, profile=True)
        rows += [
            (f"fig9/n{n}/sequential_ms", seq * 1e3, "runtime"),
            (f"fig9/n{n}/levelized_ms", lvl * 1e3, "OpenMP-paradigm"),
            (f"fig9/n{n}/futures_ms", fut * 1e3, "thread-pool DAG"),
            (f"fig9/n{n}/taskflow_ms", tfl * 1e3, "work stealing (ours)"),
            (f"fig9/n{n}/taskflow_tasks_per_s", n / tfl, "throughput"),
            (f"fig9/n{n}/steals_ok", prof["steals_ok"], "scheduler counter"),
            (f"fig9/n{n}/sleep_residency", prof["sleep_residency"],
             "adaptive sleeping (energy proxy)"),
        ]
    rows.append(("fig9/peak_rss_mb", peak_rss_mb(), "memory panel"))
    return rows


if __name__ == "__main__":
    for name, val, derived in bench():
        print(f"{name},{val:.3f},{derived}")
