# Continuous-batching serve benchmark (not a paper figure: the ROADMAP
# production-serve goal). Poisson request arrivals against the resident
# engine vs the per-call baseline.
"""tokens/sec + latency percentiles under a Poisson arrival trace.

Two modes over identical (seeded) traces:

* ``continuous`` — one resident ServeEngine; each arrival is ``submit()``-ed
  at its trace time and joins the running batch at the next chunk boundary.
* ``per-call``   — the pre-continuous-batching behaviour: each arrival is
  served by its own ``generate([prompt])`` call on a dedicated engine
  (requests queue FIFO behind one another; no cross-request batching).

Reported per mode: wall-clock tokens/sec and p50/p99 request latency
(submit -> result). The derived column of the continuous rows shows the
speedup over the per-call baseline.
"""
from __future__ import annotations

import time
from typing import Iterator, List, Tuple


def _trace(rng, n: int, rate_hz: float, lens: Tuple[int, ...],
           max_new: int):
    """Poisson arrivals: (arrival_time, prompt, max_new) tuples."""
    t = 0.0
    out = []
    for _ in range(n):
        t += rng.exponential(1.0 / rate_hz)
        size = int(rng.choice(lens))
        prompt = rng.integers(0, 500, size=size).astype("int32")
        out.append((t, prompt, max_new))
    return out


def _percentiles(lat: List[float]) -> Tuple[float, float]:
    import numpy as np
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 99)))


def bench(quick: bool = False,
          impl: str = None) -> Iterator[Tuple[str, str, str]]:
    """impl picks the continuous engine's paged read path ("pallas" /
    "xla" / "gather"); None = engine default (REPRO_PAGED_IMPL env or
    backend-based, see repro.kernels.ops.default_paged_impl)."""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve.engine import ServeEngine

    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 8 if quick else 32
    max_new = 8 if quick else 32
    chunk = 4 if quick else 8
    # arrival rate is chosen to SATURATE the server (offered load > service
    # rate): continuous batching is a throughput/tail-latency mechanism for
    # overlapping requests — at sub-saturation rates a single-stream CPU
    # serves per-call requests back-to-back and nothing can be batched
    rate = 200.0 if quick else 20.0
    lens = (8, 12) if quick else (16, 24, 32)
    rng = np.random.default_rng(0)
    trace = _trace(rng, n_req, rate, lens, max_new)
    total_tokens = n_req * max_new

    # size the paged geometry to the trace: every decode row pays a gather
    # over max_seq_len key positions, so an oversized table width taxes the
    # whole batch (the same sizing a production deployment does)
    bs = 8
    max_seq = -(-(max(lens) + max_new) // bs) * bs

    # ---------------------------------------------------------- continuous
    with ServeEngine(cfg, params, decode_chunk=chunk, block_size=bs,
                     max_seq_len=max_seq, kv_blocks=128,
                     paged_impl=impl) as eng:
        read_impl = eng.paged_impl
        # warm-up: one request per distinct prompt length compiles the paged
        # chunk program + that length's (padded) prefill and scatter — the
        # engine pads admission groups to max_admit, so group-size variance
        # under Poisson arrivals triggers no further compilation
        for s in lens:
            warm = [p for _, p, _ in trace if len(p) == s][:1]
            if warm:
                eng.generate(warm, max_new=chunk + 1)
        for k in eng.stats:
            eng.stats[k] = 0
        t0 = time.perf_counter()
        reqs = []
        for at, prompt, mn in trace:
            now = time.perf_counter() - t0
            if now < at:
                time.sleep(at - now)
            reqs.append((at, eng.submit(prompt, mn)))
        lat = []
        for at, r in reqs:
            eng.result(r, timeout=600.0)
            # latency from NOMINAL arrival to completion (includes any
            # admission queueing — same clock the baseline is held to)
            lat.append(r.finished_at - t0 - at)
        cont_dt = time.perf_counter() - t0
        cont_p50, cont_p99 = _percentiles(lat)
        stats = dict(eng.stats)

    # ------------------------------------------------------------ per-call
    with ServeEngine(cfg, params, decode_chunk=chunk) as base:
        # warm the GROUPED path the baseline times (its prefill max_len and
        # contiguous chunk program differ from the paged engine's)
        for s in lens:
            warm = [p for _, p, _ in trace if len(p) == s][:1]
            if warm:
                base._generate_grouped(warm, max_new)
        t0 = time.perf_counter()
        lat = []
        for at, prompt, mn in trace:
            now = time.perf_counter() - t0
            if now < at:
                time.sleep(at - now)
            base._generate_grouped([prompt], mn)  # one call per request
            # arrival-to-completion: a request that arrived while earlier
            # calls were still running has been queueing the whole time
            lat.append(time.perf_counter() - t0 - at)
        base_dt = time.perf_counter() - t0
        base_p50, base_p99 = _percentiles(lat)

    yield ("serve_continuous_tok_per_s", f"{total_tokens/cont_dt:.1f}",
           f"{base_dt/cont_dt:.2f}x_per_call")
    yield ("serve_continuous_paged_impl", read_impl, "")
    yield ("serve_continuous_p50_ms", f"{cont_p50*1e3:.0f}",
           f"{base_p50/max(cont_p50,1e-9):.2f}x_per_call")
    yield ("serve_continuous_p99_ms", f"{cont_p99*1e3:.0f}",
           f"{base_p99/max(cont_p99,1e-9):.2f}x_per_call")
    yield ("serve_percall_tok_per_s", f"{total_tokens/base_dt:.1f}", "")
    yield ("serve_percall_p50_ms", f"{base_p50*1e3:.0f}", "")
    yield ("serve_percall_p99_ms", f"{base_p99*1e3:.0f}", "")
    yield ("serve_continuous_admits", str(stats["admitted"]),
           f"{stats['prefills']}_prefill_launches")
    yield ("serve_continuous_decode_cycles", str(stats["decode_cycles"]),
           f"{stats['admit_parks']}_admit_parks")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--impl", default=None,
                    choices=("pallas", "xla", "gather"),
                    help="paged read path of the continuous engine")
    args = ap.parse_args()
    for name, val, derived in bench(quick=args.quick, impl=args.impl):
        print(f"{name},{val},{derived}")
