# Continuous-batching serve benchmark (not a paper figure: the ROADMAP
# production-serve goal). Poisson request arrivals against the resident
# engine vs the per-call baseline.
"""tokens/sec + latency percentiles under a Poisson arrival trace.

Two modes over identical (seeded) traces:

* ``continuous`` — one resident ServeEngine; each arrival is ``submit()``-ed
  at its trace time and joins the running batch at the next chunk boundary.
* ``per-call``   — the pre-continuous-batching behaviour: each arrival is
  served by its own per-call grouped pipeline run on a dedicated engine
  (requests queue FIFO behind one another; no cross-request batching).

Prompt-length distributions (``--prompt-dist``):

* ``choice``    — a few fixed lengths (the original workload);
* ``lognormal`` — a heavy-tailed mix (most prompts short, a fat tail of
  long ones, quantized to multiples of 4). This is the workload two-phase
  admission is for: chunked prefill keeps long prompts from stalling the
  batch, prompt-only admission keeps the tail from hogging pool capacity
  it has not earned yet, and mixed lengths admit together (no buckets).

Reported per mode: wall-clock tokens/sec, p50/p99 request latency
(nominal arrival -> result) and — continuous only — p50/p99 ADMISSION
latency (nominal arrival -> first admission into the running batch: the
queueing delay the prompt-only block budget is meant to shrink) plus
p50/p99 TTFT. The derived column of the continuous rows shows the speedup
over the per-call baseline.

The continuous engine runs with a live :class:`repro.obs.Observability`:
every percentile row is read back from the metrics registry (exact
nearest-rank percentiles — the bench records each request's latency into
a registry histogram rather than a private list, and TTFT comes from the
engine's own ``serve.ttft_s`` instrumentation), and ``trace_path`` writes
the run's Chrome trace-event JSON artifact alongside ``BENCH_*.json``.

``--prefix-share`` (:func:`bench_prefix_share`) swaps in the prefix-cache
workload instead: a small pool of LONG shared prefixes crossed with short
unique suffixes under Poisson arrivals, replayed over the identical trace
twice — once with the prefix cache off (the cold baseline) and once with
it on (steady-state: the compile warm-up burst also seeds the cache).
Reported: cache hit-rate, prefill tokens saved, CoW forks, and warm-vs-
cold admission/TTFT p50/p99 — the two latencies copy-on-write prefix
sharing exists to shrink.
"""
from __future__ import annotations

import time
from typing import Iterator, List, Tuple

PROMPT_DISTS = ("choice", "lognormal")


def _sample_lens(rng, n: int, dist: str, quick: bool):
    import numpy as np
    if dist == "lognormal":
        cap = 32 if quick else 64
        raw = rng.lognormal(mean=np.log(10.0), sigma=0.8, size=n)
        # quantize to multiples of 4: bounds the per-call baseline's
        # per-length compile count while keeping the tail heavy
        return np.clip((np.ceil(raw / 4) * 4).astype(int), 4, cap)
    lens = (8, 12) if quick else (16, 24, 32)
    return np.asarray([int(rng.choice(lens)) for _ in range(n)])


def _trace(rng, sizes, rate_hz: float, max_new: int):
    """Poisson arrivals: (arrival_time, prompt, max_new) tuples."""
    t = 0.0
    out = []
    for size in sizes:
        t += rng.exponential(1.0 / rate_hz)
        prompt = rng.integers(0, 500, size=int(size)).astype("int32")
        out.append((t, prompt, max_new))
    return out


def _percentiles(lat: List[float]) -> Tuple[float, float]:
    import numpy as np
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 99)))


def bench(quick: bool = False,
          impl: str = None,
          prompt_dist: str = "choice",
          trace_path: str = None) -> Iterator[Tuple[str, str, str]]:
    """impl picks the continuous engine's paged read path ("pallas" /
    "xla" / "gather"); None = engine default (REPRO_PAGED_IMPL env or
    backend-based, see repro.kernels.ops.default_paged_impl).
    prompt_dist: "choice" (fixed lengths) or "lognormal" (heavy tail).
    trace_path: write the continuous run's Chrome trace-event JSON here."""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import lm
    from repro.obs import Observability
    from repro.serve.engine import ServeEngine

    if prompt_dist not in PROMPT_DISTS:
        raise ValueError(f"prompt_dist={prompt_dist!r}: expected one of "
                         f"{PROMPT_DISTS}")
    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 8 if quick else 32
    max_new = 8 if quick else 32
    chunk = 4 if quick else 8
    # arrival rate is chosen to SATURATE the server (offered load > service
    # rate): continuous batching is a throughput/tail-latency mechanism for
    # overlapping requests — at sub-saturation rates a single-stream CPU
    # serves per-call requests back-to-back and nothing can be batched
    rate = 200.0 if quick else 20.0
    rng = np.random.default_rng(0)
    sizes = _sample_lens(rng, n_req, prompt_dist, quick)
    trace = _trace(rng, sizes, rate, max_new)
    total_tokens = n_req * max_new

    # size the paged geometry to the trace's TAIL: two-phase admission means
    # only live tokens tax the pool, but the table width still keys on the
    # longest admissible sequence
    bs = 8
    max_seq = -(-(int(sizes.max()) + max_new) // bs) * bs
    distinct = sorted({len(p) for _, p, _ in trace})
    # a 2-block prefill window: the trace's tail prompts stream across
    # multiple cycles instead of serializing one long launch
    prefill_chunk = 2 * bs

    # ---------------------------------------------------------- continuous
    obs = Observability()
    with ServeEngine(cfg, params, decode_chunk=chunk, block_size=bs,
                     max_seq_len=max_seq, kv_blocks=128,
                     prefill_chunk=prefill_chunk,
                     paged_impl=impl, obs=obs) as eng:
        read_impl = eng.paged_impl
        # warm-up: chunked prefill keys compiled shapes on the pow2-rounded
        # window size, so one request per distinct pow2 bucket (not per
        # length) compiles the prefill programs...
        buckets = {1 << max(0, s - 1).bit_length(): s for s in distinct}
        for s in buckets.values():
            warm = [p for _, p, _ in trace if len(p) == s][:1]
            if warm:
                eng.generate(warm, max_new=chunk + 1)
        # ... and one saturating mixed-length burst compiles the group-merge
        # / growth / retire scatter shapes (pow2-padded, so a burst covers
        # every size the trace can trigger)
        eng.generate([p for _, p, _ in trace], max_new=chunk + 1)
        for k in eng.stats:
            eng.stats[k] = 0
        # drop warm-up spans/counts; metric handles the engine cached at
        # bind time stay valid (in-place registry reset)
        obs.reset()
        # request latencies go into registry histograms too, so every
        # percentile row below reads back from ONE source (exact
        # nearest-rank percentiles at these request counts)
        h_lat = obs.metrics.histogram("bench.request_latency_s")
        h_adm = obs.metrics.histogram("bench.admission_latency_s")
        t0 = time.perf_counter()
        reqs = []
        for at, prompt, mn in trace:
            now = time.perf_counter() - t0
            if now < at:
                time.sleep(at - now)
            reqs.append((at, eng.submit(prompt, mn)))
        for at, r in reqs:
            eng.result(r, timeout=600.0)
            # latency from NOMINAL arrival to completion (includes any
            # admission queueing — same clock the baseline is held to)
            h_lat.record(r.finished_at - t0 - at)
            # admission latency: nominal arrival -> first admission (the
            # wait the prompt-only block budget is meant to shrink)
            h_adm.record(max(0.0, r.admitted_at - t0 - at))
        cont_dt = time.perf_counter() - t0
        cont_p50, cont_p99 = h_lat.percentile(50), h_lat.percentile(99)
        adm_p50, adm_p99 = h_adm.percentile(50), h_adm.percentile(99)
        ttft = obs.metrics.get("serve.ttft_s").summary()
        stats = dict(eng.stats)
        if trace_path:
            obs.export(trace_path)

    # ------------------------------------------------------------ per-call
    with ServeEngine(cfg, params, decode_chunk=chunk) as base:
        # warm the GROUPED baseline path per distinct length (its prefill
        # max_len and contiguous chunk program key on the prompt length)
        for s in distinct:
            warm = [p for _, p, _ in trace if len(p) == s][:1]
            if warm:
                base._generate_grouped(warm, max_new)
        t0 = time.perf_counter()
        lat = []
        for at, prompt, mn in trace:
            now = time.perf_counter() - t0
            if now < at:
                time.sleep(at - now)
            base._generate_grouped([prompt], mn)  # one call per request
            # arrival-to-completion: a request that arrived while earlier
            # calls were still running has been queueing the whole time
            lat.append(time.perf_counter() - t0 - at)
        base_dt = time.perf_counter() - t0
        base_p50, base_p99 = _percentiles(lat)

    yield ("serve_continuous_tok_per_s", f"{total_tokens/cont_dt:.1f}",
           f"{base_dt/cont_dt:.2f}x_per_call")
    yield ("serve_continuous_paged_impl", read_impl, "")
    yield ("serve_prompt_dist", prompt_dist,
           f"lens_{int(sizes.min())}_{int(sizes.max())}")
    yield ("serve_continuous_p50_ms", f"{cont_p50*1e3:.0f}",
           f"{base_p50/max(cont_p50,1e-9):.2f}x_per_call")
    yield ("serve_continuous_p99_ms", f"{cont_p99*1e3:.0f}",
           f"{base_p99/max(cont_p99,1e-9):.2f}x_per_call")
    yield ("serve_admission_p50_ms", f"{adm_p50*1e3:.0f}", "")
    yield ("serve_admission_p99_ms", f"{adm_p99*1e3:.0f}", "")
    yield ("serve_ttft_p50_ms", f"{ttft['p50']*1e3:.0f}",
           f"count_{ttft['count']}")
    yield ("serve_ttft_p99_ms", f"{ttft['p99']*1e3:.0f}", "")
    yield ("serve_percall_tok_per_s", f"{total_tokens/base_dt:.1f}", "")
    yield ("serve_percall_p50_ms", f"{base_p50*1e3:.0f}", "")
    yield ("serve_percall_p99_ms", f"{base_p99*1e3:.0f}", "")
    yield ("serve_continuous_admits", str(stats["admitted"]),
           f"{stats['prefills']}_prefill_launches")
    yield ("serve_continuous_decode_cycles", str(stats["decode_cycles"]),
           f"{stats['admit_parks']}_admit_parks")
    yield ("serve_continuous_growth", str(stats["grown_blocks"]),
           f"{stats['preempted']}_preemptions_"
           f"{stats['prefill_windows']}_windows")
    if trace_path:
        yield ("serve_trace_spans", str(len(obs.tracer)), trace_path)


def bench_prefix_share(quick: bool = False,
                       impl: str = None,
                       trace_path: str = None
                       ) -> Iterator[Tuple[str, str, str]]:
    """Prefix-cache workload: long shared prefixes x short unique suffixes
    under Poisson arrivals, the IDENTICAL trace replayed cold (prefix
    cache off) then warm (on, cache seeded by the compile warm-up burst).
    Every latency row pairs the warm value with its cold counterpart, so
    the cache's effect is read off one run."""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import lm
    from repro.obs import Observability
    from repro.serve.engine import ServeEngine

    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 8 if quick else 24
    max_new = 4 if quick else 16
    chunk = 4 if quick else 8
    n_prefix = 2 if quick else 4
    prefix_len = 48 if quick else 96
    # offered load must exceed service rate (see bench() above): the
    # admission/TTFT deltas only exist while requests queue
    rate = 200.0 if quick else 20.0
    bs = 8
    prefill_chunk = 2 * bs
    # the pool is sized to make admission BLOCK-limited: cold admissions
    # budget the full prompt footprint and queue behind retirements, warm
    # ones budget only the unique suffix (shared chains are parked, counted
    # once) and seat immediately — the admission-latency win under load
    kv_blocks = 28 if quick else 80

    rng = np.random.default_rng(0)
    prefixes = [rng.integers(0, 500, size=prefix_len).astype("int32")
                for _ in range(n_prefix)]
    t, trace = 0.0, []
    for i in range(n_req):
        t += rng.exponential(1.0 / rate)
        suffix = rng.integers(0, 500, size=int(rng.integers(4, 9))
                              ).astype("int32")
        prompt = np.concatenate([prefixes[i % n_prefix], suffix])
        trace.append((t, prompt, max_new))
    total_tokens = n_req * max_new
    max_seq = -(-(max(len(p) for _, p, _ in trace) + max_new) // bs) * bs

    def _run(prefix_cache: bool) -> dict:
        obs = Observability()
        with ServeEngine(cfg, params, decode_chunk=chunk, block_size=bs,
                         max_seq_len=max_seq, kv_blocks=kv_blocks,
                         prefill_chunk=prefill_chunk, paged_impl=impl,
                         prefix_cache=prefix_cache, obs=obs) as eng:
            # two saturating bursts compile every shape the trace can
            # trigger: the first is cold (window-0 prefill, growth,
            # retire); the second runs against the now-seeded cache, so
            # with the cache ON it also compiles the HIT-path shapes
            # (fork copy, hit-only merge, suffix windows) — the measured
            # pass is the steady state, not the cold start
            for _ in range(2):
                eng.generate([p for _, p, _ in trace], max_new=chunk + 1)
            for k in eng.stats:
                eng.stats[k] = 0
            obs.reset()
            h_adm = obs.metrics.histogram("bench.admission_latency_s")
            t0 = time.perf_counter()
            reqs = []
            for at, prompt, mn in trace:
                now = time.perf_counter() - t0
                if now < at:
                    time.sleep(at - now)
                reqs.append((at, eng.submit(prompt, mn)))
            for at, r in reqs:
                eng.result(r, timeout=600.0)
                h_adm.record(max(0.0, r.admitted_at - t0 - at))
            dt = time.perf_counter() - t0
            out = {
                "dt": dt,
                "adm_p50": h_adm.percentile(50),
                "adm_p99": h_adm.percentile(99),
                "ttft": obs.metrics.get("serve.ttft_s").summary(),
                "stats": dict(eng.stats),
                "impl": eng.paged_impl,
            }
            if prefix_cache and trace_path:
                obs.export(trace_path)
        return out

    cold = _run(False)
    warm = _run(True)
    st = warm["stats"]
    hit_rate = st["prefix_hits"] / max(1, st["admitted"])
    yield ("serve_prefix_hit_rate", f"{hit_rate:.3f}",
           f"{st['prefix_hits']}_of_{st['admitted']}_admissions")
    yield ("serve_prefix_tokens_saved", str(st["prefix_tokens_saved"]),
           f"{st['cow_forks']}_cow_forks")
    yield ("serve_prefix_tok_per_s", f"{total_tokens/warm['dt']:.1f}",
           f"{cold['dt']/warm['dt']:.2f}x_cold")
    yield ("serve_prefix_admission_p50_ms", f"{warm['adm_p50']*1e3:.0f}",
           f"cold_{cold['adm_p50']*1e3:.0f}ms")
    yield ("serve_prefix_admission_p99_ms", f"{warm['adm_p99']*1e3:.0f}",
           f"cold_{cold['adm_p99']*1e3:.0f}ms")
    yield ("serve_prefix_ttft_p50_ms", f"{warm['ttft']['p50']*1e3:.0f}",
           f"cold_{cold['ttft']['p50']*1e3:.0f}ms")
    yield ("serve_prefix_ttft_p99_ms", f"{warm['ttft']['p99']*1e3:.0f}",
           f"cold_{cold['ttft']['p99']*1e3:.0f}ms")
    yield ("serve_prefix_workload",
           f"{n_prefix}x{prefix_len}_prefixes", warm["impl"])
    yield ("serve_cold_admission_p50_ms", f"{cold['adm_p50']*1e3:.0f}", "")
    yield ("serve_cold_ttft_p50_ms", f"{cold['ttft']['p50']*1e3:.0f}", "")


def bench_mesh(quick: bool = False,
               mesh_model: int = 2) -> Iterator[Tuple[str, str, str]]:
    """Tensor-parallel sharded serving vs the single-device engine over one
    seeded Poisson trace (see docs/sharded_serving.md).

    Both engines serve the IDENTICAL trace; greedy decode must be
    bit-exact across them (asserted — the bench doubles as a parity
    gate). Reported: mesh vs single tok/s and request latency, the
    per-device KV pool footprint (the whole point: ~1/N per device), and
    admission capacity — how many pool blocks fit a FIXED per-device byte
    budget (the single-device pool size) once each block's per-device
    slice shrinks by the mesh factor.

    Needs >= mesh_model JAX devices; on CPU set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before any jax
    import (the ``__main__`` CLI and ``benchmarks.run`` do this for you).
    """
    import dataclasses
    import os

    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_ctx, small_mesh
    from repro.models import lm
    from repro.serve.engine import ServeEngine

    if jax.device_count() < mesh_model:
        raise RuntimeError(
            f"mesh_model={mesh_model} needs that many devices, have "
            f"{jax.device_count()}; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={mesh_model} before "
            "any jax import")

    # widened smoke config: the stock smoke model has only 2 KV heads, so
    # KV=4/H=8 lets both 2- and 4-way model axes divide the pool by head
    cfg = dataclasses.replace(get_config("stablelm-1.6b").smoke(),
                              num_heads=8, num_kv_heads=4)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 8 if quick else 16
    max_new = 8 if quick else 24
    chunk = 4 if quick else 8
    rate = 200.0 if quick else 40.0
    bs = 8
    kv_blocks = 64 if quick else 128
    rng = np.random.default_rng(0)
    sizes = _sample_lens(rng, n_req, "choice", quick)
    trace = _trace(rng, sizes, rate, max_new)
    total_tokens = n_req * max_new
    max_seq = -(-(int(sizes.max()) + max_new) // bs) * bs

    # the env knob must not leak into the ctx=None baseline (the CI mesh
    # leg exports REPRO_MESH_MODEL for the test matrix)
    env_mesh = os.environ.pop("REPRO_MESH_MODEL", None)
    try:
        def _run(ctx):
            with ServeEngine(cfg, params, ctx=ctx, decode_chunk=chunk,
                             block_size=bs, max_seq_len=max_seq,
                             kv_blocks=kv_blocks,
                             prefill_chunk=2 * bs) as eng:
                # one saturating burst compiles every shape the trace hits
                eng.generate([p for _, p, _ in trace], max_new=chunk + 1)
                for k in eng.stats:
                    eng.stats[k] = 0
                pool_full = int(eng._pkv.nbytes)
                pool_dev = int(
                    eng._pkv.addressable_shards[0].data.nbytes)
                t0 = time.perf_counter()
                reqs = []
                for at, prompt, mn in trace:
                    now = time.perf_counter() - t0
                    if now < at:
                        time.sleep(at - now)
                    reqs.append(eng.submit(prompt, mn))
                outs = [eng.result(r, timeout=600.0) for r in reqs]
                lat = [r.finished_at - t0 - at
                       for (at, _, _), r in zip(trace, reqs)]
                dt = time.perf_counter() - t0
                stats = dict(eng.stats)
            return dict(dt=dt, outs=outs, lat=lat, pool_full=pool_full,
                        pool_dev=pool_dev, stats=stats)

        single = _run(None)
        mesh = _run(make_ctx(small_mesh(data=1, model=mesh_model)))
    finally:
        if env_mesh is not None:
            os.environ["REPRO_MESH_MODEL"] = env_mesh

    mismatch = [i for i, (a, b) in
                enumerate(zip(single["outs"], mesh["outs"]))
                if not np.array_equal(a, b)]
    if mismatch:
        raise RuntimeError(
            f"mesh decode diverged from single-device on requests "
            f"{mismatch}: the no-accidental-gather TP path must be "
            "bit-exact (greedy)")
    p50s, p99s = _percentiles(single["lat"])
    p50m, p99m = _percentiles(mesh["lat"])
    ratio = single["pool_dev"] / max(1, mesh["pool_dev"])
    # admission capacity at a fixed per-device byte budget: with each
    # block's per-device slice 1/N the size, N-fold the blocks fit in the
    # bytes one device used to spend on the whole pool
    blk_dev = mesh["pool_dev"] / kv_blocks
    capacity = int(single["pool_dev"] // blk_dev)

    yield ("serve_mesh_model_axis", str(mesh_model),
           f"devices_{jax.device_count()}")
    yield ("serve_mesh_parity", "exact",
           f"{n_req}_requests_vs_single_device")
    yield ("serve_mesh_tok_per_s", f"{total_tokens/mesh['dt']:.1f}",
           f"{single['dt']/mesh['dt']:.2f}x_single")
    yield ("serve_mesh_single_tok_per_s",
           f"{total_tokens/single['dt']:.1f}", "")
    yield ("serve_mesh_p50_ms", f"{p50m*1e3:.0f}",
           f"single_{p50s*1e3:.0f}ms")
    yield ("serve_mesh_p99_ms", f"{p99m*1e3:.0f}",
           f"single_{p99s*1e3:.0f}ms")
    yield ("serve_mesh_pool_device_bytes", str(mesh["pool_dev"]),
           f"{ratio:.1f}x_smaller_than_single")
    yield ("serve_mesh_pool_full_bytes", str(mesh["pool_full"]),
           f"{kv_blocks}_blocks")
    yield ("serve_mesh_capacity_blocks", str(capacity),
           f"vs_{kv_blocks}_at_fixed_device_bytes")
    yield ("serve_mesh_growth", str(mesh["stats"]["grown_blocks"]),
           f"{mesh['stats']['prefill_windows']}_windows_"
           f"{mesh['stats']['preempted']}_preemptions")


if __name__ == "__main__":
    import argparse
    import os
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--impl", default=None,
                    choices=("pallas", "xla", "gather"),
                    help="paged read path of the continuous engine")
    ap.add_argument("--prompt-dist", default="choice",
                    choices=PROMPT_DISTS,
                    help="prompt-length distribution of the trace "
                         "(lognormal = heavy tail)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="run the shared-prefix workload (cold vs warm "
                         "prefix cache over one trace) instead")
    ap.add_argument("--mesh-model", type=int, default=None, metavar="N",
                    help="run the tensor-parallel mesh workload instead: "
                         "N-way KV-head-sharded engine vs single-device "
                         "over one trace (bit-exact parity asserted)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the continuous run's Chrome trace-event "
                         "JSON here")
    args = ap.parse_args()
    if args.mesh_model:
        # must happen before the first jax import inside bench_mesh
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.mesh_model}").strip()
    rows = (bench_mesh(quick=args.quick, mesh_model=args.mesh_model)
            if args.mesh_model else
            bench_prefix_share(quick=args.quick, impl=args.impl,
                               trace_path=args.trace)
            if args.prefix_share else
            bench(quick=args.quick, impl=args.impl,
                  prompt_dist=args.prompt_dist, trace_path=args.trace))
    for name, val, derived in rows:
        print(f"{name},{val},{derived}")
