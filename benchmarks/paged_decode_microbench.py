# Decode-step microbench for the paged read path (ROADMAP production-serve
# goal; not a paper figure). Occupancy sweep: gather-free reads vs the
# materializing gather oracle.
"""Paged decode-attention read path across pool occupancies.

The gather oracle pays O(capacity) per row per step — it materializes and
attends over ``max_blocks * block_size`` positions regardless of the rows'
true lengths. The gather-free paths (``repro.kernels.paged_attention``)
bound their work by ``max(lengths)``, so their cost follows *occupancy*:

* ``paged_read_*``  — the XLA traced-bound page loop (the off-TPU serve
  default) at low / mid / full occupancy, with the gather oracle timed on
  identical inputs. The derived column reports the speedup; low occupancy
  (short rows in a large pool) is where paging pays.
* ``pallas_interpret_read_low_occ_ms`` — the Pallas kernel through the
  interpreter, for the trajectory record only: per-grid-step interpreter
  overhead dominates on CPU (it is a correctness tool here; the Mosaic
  lowering on TPU is the perf path).
* ``decode_step_*`` — end-to-end ``lm.decode_step_paged`` (all layers,
  projections, MLP) at low occupancy, paged vs gather read path.
"""
from __future__ import annotations

import functools
import time
from typing import Iterator, Tuple


def _time_ms(fn, iters: int) -> float:
    fn().block_until_ready()                    # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e3


def bench(quick: bool = False) -> Iterator[Tuple[str, str, str]]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.kernels.paged_attention import paged_attention
    from repro.models import lm
    from repro.serve.kvcache import gather_read_attention

    B, H, KV, hd = 8, 8, 4, 64
    bs = 16
    mb = 16 if quick else 64                    # capacity per row
    iters = 20 if quick else 100
    N = B * mb + 1
    cap = mb * bs

    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    q = jax.random.normal(ks[0], (B, H, hd))
    pool_kv = jax.random.normal(ks[1], (2, N, KV, bs, hd))
    tables = jnp.asarray(
        1 + np.arange(B * mb, dtype=np.int32).reshape(B, mb))

    gather_read = jax.jit(gather_read_attention)  # the shared oracle

    occupancies = [("low", bs - 1), ("mid", cap // 2 - 1),
                   ("full", cap - 1)]
    for occ_name, pos in occupancies:
        lengths = jnp.full((B,), pos, jnp.int32)
        t_paged = _time_ms(
            lambda: paged_attention(q, pool_kv, tables, lengths,
                                    impl="xla"), iters)
        t_gather = _time_ms(
            lambda: gather_read(q, pool_kv, tables, lengths), iters)
        yield (f"paged_read_{occ_name}_occ_ms", f"{t_paged:.3f}",
               f"{t_gather/t_paged:.2f}x_gather")
        yield (f"gather_read_{occ_name}_occ_ms", f"{t_gather:.3f}", "")

    # Pallas interpreter datapoint (trajectory record; see module docstring)
    lengths = jnp.full((B,), bs - 1, jnp.int32)
    t_pallas = _time_ms(
        lambda: paged_attention(q, pool_kv, tables, lengths,
                                impl="pallas"), max(2, iters // 10))
    yield ("pallas_interpret_read_low_occ_ms", f"{t_pallas:.3f}",
           "interpret_mode")

    # end-to-end decode step at low occupancy (smoke model: all layers)
    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    mb2 = 32                            # large pool: the short rows below
    #                                     sit at ~3% of per-row capacity
    N2 = B * mb2 + 1
    pool = jnp.zeros((cfg.num_layers, 2, N2, cfg.num_kv_heads, bs, cfg.hd),
                     jnp.bfloat16)
    tables2 = jnp.asarray(
        1 + np.arange(B * mb2, dtype=np.int32).reshape(B, mb2))
    lengths2 = jnp.full((B,), bs - 1, jnp.int32)
    token = jnp.ones((B,), jnp.int32)
    active = jnp.ones((B,), bool)
    times = {}
    for impl in ("xla", "gather"):
        step = jax.jit(functools.partial(lm.decode_step_paged, cfg,
                                         impl=impl))
        times[impl] = _time_ms(
            lambda: step(params, pool, tables2, lengths2, token, active)[0],
            max(5, iters // 4))
    yield ("decode_step_paged_low_occ_ms", f"{times['xla']:.3f}",
           f"{times['gather']/times['xla']:.2f}x_gather")
    yield ("decode_step_gather_low_occ_ms", f"{times['gather']:.3f}", "")


if __name__ == "__main__":
    for name, val, derived in bench(quick=True):
        print(f"{name},{val},{derived}")
