# One function per paper table. Prints ``name,value,derived`` CSV.
"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig9,...]

table2  — task/edge creation overheads (paper Table 2)
fig9    — random-DAG runtime/memory vs baselines (paper Figure 9)
fig11   — co-run throughput + utilization (paper Figure 11)
fig13   — LSDNN inference (paper Figure 13, §5.3)
fig17   — conditional-vs-unrolled memory (paper Figure 17 memory panel)
fig21   — incremental timing propagation (paper Figure 21, §5.5)
roofline— the dry-run roofline table (§Roofline), from results/dryrun.jsonl
pipeline— task-parallel pipeline throughput vs hand-rolled loop
          (Pipeflow follow-up, arXiv:2202.00717); honors --quick
serve   — continuous-batching engine under Poisson arrivals vs the
          per-call baseline (tokens/sec, p50/p99 latency); honors --quick
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true",
                    help="seconds-scale smoke sizes (tier-1 environment)")
    args = ap.parse_args()

    from . import (fig9_micro_random_dag, fig11_corun_throughput,
                   fig13_lsdnn, fig17_conditional_memory,
                   fig21_incremental_timing, pipeline_throughput,
                   roofline_report, serve_continuous, table2_task_overhead)

    suites = {
        "table2": lambda: table2_task_overhead.bench(200_000),
        "fig9": fig9_micro_random_dag.bench,
        "fig11": fig11_corun_throughput.bench,
        "fig13": fig13_lsdnn.bench,
        "fig17": fig17_conditional_memory.bench,
        "fig21": fig21_incremental_timing.bench,
        "roofline": roofline_report.bench,
        "pipeline": lambda: pipeline_throughput.bench(quick=args.quick),
        "serve": lambda: serve_continuous.bench(quick=args.quick),
    }
    only = [s for s in args.only.split(",") if s]
    failures = 0
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            for row_name, val, derived in fn():
                print(f"{row_name},{val},{derived}", flush=True)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == '__main__':
    main()
