# One function per paper table. Prints ``name,value,derived`` CSV and
# writes a BENCH_<suite>.json trajectory file per suite.
"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig9,...] [--quick]
                                            [--bench-dir DIR]

table2  — task/edge creation overheads (paper Table 2)
fig9    — random-DAG runtime/memory vs baselines (paper Figure 9)
fig11   — co-run throughput + utilization (paper Figure 11)
fig13   — LSDNN inference (paper Figure 13, §5.3)
fig17   — conditional-vs-unrolled memory (paper Figure 17 memory panel)
fig21   — incremental timing propagation (paper Figure 21, §5.5)
roofline— the dry-run roofline table (§Roofline), from results/dryrun.jsonl
pipeline— task-parallel pipeline throughput vs hand-rolled loop
          (Pipeflow follow-up, arXiv:2202.00717); honors --quick
serve   — continuous-batching engine under Poisson arrivals vs the
          per-call baseline (tokens/sec, p50/p99 latency); honors --quick;
          --prefix-share swaps in the shared-prefix workload (cold vs
          warm prefix cache over one trace: hit-rate, tokens saved,
          admission/TTFT p50/p99 deltas)
serve_slo — SLO-aware overload control: tier-0 tail TTFT uncontended vs
          under a tier-1 best-effort flood (shedding, queue-deadline
          expiry, cost-model preemption); honors --quick
serve_mesh — tensor-parallel sharded serving: --mesh-model N KV-head-
          sharded engine vs single-device over one trace (bit-exact
          parity asserted; per-device pool bytes ~1/N; admission
          capacity at fixed device memory); honors --quick. Needs N
          devices — when only serve_mesh is selected the harness forces
          CPU host devices itself, otherwise set XLA_FLAGS=
          --xla_force_host_platform_device_count=N up front
paged_decode — gather-free paged decode read path vs the gather oracle
          across pool occupancies; honors --quick
decode_overlap — async decode lookahead vs the synchronous decode loop:
          per-cycle dispatch/sync/bookkeeping wall-time breakdown and
          host-gap fraction across decode-chunk sizes; honors --quick
obs_gate — observability overhead gate: serve tok/s with the obs stack
          enabled must stay within REPRO_OBS_GATE_BUDGET (default 2%)
          of disabled; honors --quick
journal_gate — durability overhead gate: serve tok/s with the request
          WAL attached must stay within REPRO_JOURNAL_GATE_BUDGET
          (default 5%) of detached; honors --quick

Each completed suite drops ``BENCH_<suite>.json`` into --bench-dir
(default: CWD): the run config, every emitted row, the well-known
metrics (``tok_per_s`` / ``p50_ms`` / ``p99_ms`` where a suite reports
them), and provenance (git sha + ISO-8601 UTC timestamp) — the
machine-readable perf trajectory that used to exist only as stdout CSV.
The serve, serve_slo and decode_overlap suites also write their run's
Chrome trace-event JSON (``TRACE_<suite>.json``, Perfetto-loadable)
alongside.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
import traceback
from datetime import datetime, timezone

#: row-name suffix -> trajectory metric key (suite-agnostic extraction)
_METRIC_SUFFIXES = ("tok_per_s", "p50_ms", "p99_ms")


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip()
    except OSError:
        return ""


def _write_trajectory(bench_dir: str, suite: str, config: dict,
                      rows: list, elapsed_s: float) -> str:
    metrics = {}
    for name, val, _ in rows:
        for suffix in _METRIC_SUFFIXES:
            if name.endswith(suffix):
                try:
                    metrics[name] = float(val)
                except ValueError:
                    pass
    payload = {
        "suite": suite,
        "config": config,
        "git_sha": _git_sha(),
        "timestamp": time.time(),
        "timestamp_iso": datetime.now(timezone.utc).isoformat(),
        "elapsed_s": round(elapsed_s, 3),
        "rows": [{"name": n, "value": v, "derived": d} for n, v, d in rows],
        "metrics": metrics,
    }
    os.makedirs(bench_dir, exist_ok=True)
    path = os.path.join(bench_dir, f"BENCH_{suite}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true",
                    help="seconds-scale smoke sizes (tier-1 environment)")
    ap.add_argument("--bench-dir", default=".",
                    help="where BENCH_<suite>.json trajectory files land")
    ap.add_argument("--prompt-dist", default="choice",
                    choices=("choice", "lognormal"),
                    help="serve suite prompt-length distribution "
                         "(lognormal = heavy tail)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="serve suite: shared-prefix workload, cold vs "
                         "warm prefix cache over one trace")
    ap.add_argument("--mesh-model", type=int, default=2, metavar="N",
                    help="serve_mesh suite: width of the mesh 'model' "
                         "axis (default 2)")
    args = ap.parse_args()

    only_pre = [s for s in args.only.split(",") if s]
    if only_pre == ["serve_mesh"]:
        # the mesh suite needs N devices and jax reads XLA_FLAGS exactly
        # once at backend init — safe to force here only when no other
        # suite shares the process
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.mesh_model}").strip()

    from . import (decode_overlap_microbench, fig9_micro_random_dag,
                   fig11_corun_throughput, fig13_lsdnn,
                   fig17_conditional_memory, fig21_incremental_timing,
                   journal_overhead_gate, obs_overhead_gate,
                   paged_decode_microbench, pipeline_throughput,
                   roofline_report, serve_continuous, serve_slo,
                   table2_task_overhead)

    # trace artifacts land next to the BENCH_*.json they belong to
    os.makedirs(args.bench_dir, exist_ok=True)

    def _serve_mesh_rows():
        import jax
        if jax.device_count() < args.mesh_model:
            # a 1-device default run stays green; the CI mesh leg sets
            # XLA_FLAGS at the job level so the suite actually runs there
            print(f"# serve_mesh: skipped — {jax.device_count()} "
                  f"device(s) < mesh_model={args.mesh_model} (set "
                  "XLA_FLAGS=--xla_force_host_platform_device_count="
                  f"{args.mesh_model})", flush=True)
            return iter(())
        return serve_continuous.bench_mesh(quick=args.quick,
                                           mesh_model=args.mesh_model)

    def _trace(suite: str) -> str:
        return os.path.join(args.bench_dir, f"TRACE_{suite}.json")

    suites = {
        "table2": lambda: table2_task_overhead.bench(200_000),
        "fig9": fig9_micro_random_dag.bench,
        "fig11": fig11_corun_throughput.bench,
        "fig13": fig13_lsdnn.bench,
        "fig17": fig17_conditional_memory.bench,
        "fig21": fig21_incremental_timing.bench,
        "roofline": roofline_report.bench,
        "pipeline": lambda: pipeline_throughput.bench(quick=args.quick),
        "serve": lambda: (
            serve_continuous.bench_prefix_share(
                quick=args.quick, trace_path=_trace("serve"))
            if args.prefix_share else
            serve_continuous.bench(
                quick=args.quick, prompt_dist=args.prompt_dist,
                trace_path=_trace("serve"))),
        "serve_slo": lambda: serve_slo.bench(
            quick=args.quick, trace_path=_trace("serve_slo")),
        "serve_mesh": lambda: _serve_mesh_rows(),
        "paged_decode":
            lambda: paged_decode_microbench.bench(quick=args.quick),
        "decode_overlap":
            lambda: decode_overlap_microbench.bench(
                quick=args.quick, trace_path=_trace("decode_overlap")),
        "obs_gate": lambda: obs_overhead_gate.bench(quick=args.quick),
        "journal_gate":
            lambda: journal_overhead_gate.bench(quick=args.quick),
    }
    config = {"quick": args.quick, "only": args.only,
              "prompt_dist": args.prompt_dist,
              "prefix_share": args.prefix_share,
              "mesh_model": args.mesh_model,
              "mesh_model_env": os.environ.get("REPRO_MESH_MODEL", ""),
              "paged_impl_env": os.environ.get("REPRO_PAGED_IMPL", ""),
              "async_decode_env": os.environ.get("REPRO_ASYNC_DECODE", ""),
              "obs_gate_budget_env":
                  os.environ.get("REPRO_OBS_GATE_BUDGET", ""),
              "journal_gate_budget_env":
                  os.environ.get("REPRO_JOURNAL_GATE_BUDGET", "")}
    only = [s for s in args.only.split(",") if s]
    failures = 0
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = []
            for row_name, val, derived in fn():
                rows.append((row_name, val, derived))
                print(f"{row_name},{val},{derived}", flush=True)
            elapsed = time.time() - t0
            path = _write_trajectory(args.bench_dir, name, config, rows,
                                     elapsed)
            print(f"# {name} done in {elapsed:.1f}s -> {path}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == '__main__':
    main()
