"""Paper Table 2: overhead of task-graph creation.

Reports S_task (static size per task node, bytes), T_task / T_edge
(amortized creation time over 1M operations), and rho_v (graph size where
creation overhead drops below v% of a fixed per-task work quantum), exactly
the table's columns.
"""
from __future__ import annotations

import sys
import time

from repro.core import Taskflow
from repro.core.graph import Node


def _size_of_node() -> int:
    tf = Taskflow()
    t = tf.static(lambda: None)
    n = t._node
    size = sys.getsizeof(n)
    for slot in Node.__slots__:
        try:
            size += sys.getsizeof(getattr(n, slot))
        except AttributeError:
            pass
    return size


def bench(n_ops: int = 1_000_000):
    fn = lambda: None  # noqa: E731
    t0 = time.perf_counter()
    tf = Taskflow()
    tasks = [tf.static(fn) for _ in range(n_ops)]
    t_task = (time.perf_counter() - t0) / n_ops

    t0 = time.perf_counter()
    for i in range(0, n_ops - 1, 2):
        tasks[i].precede(tasks[i + 1])
    t_edge = (time.perf_counter() - t0) / (n_ops // 2)

    s_task = _size_of_node()

    # rho_v: graph size where (creation time)/(creation + execution of a
    # 1us work quantum) < v% — derived, matching the paper's definition
    quantum = 1e-6
    rows = []
    for v in (10, 5, 1):
        # n*(t_task) < v% * n*(t_task + quantum + t_exec_overhead)
        # per-task ratio is size-independent in our runtime; report the
        # break-even work multiple instead (paper's rho via per-task cost)
        rho = t_task / (v / 100.0) / quantum
        rows.append((f"rho_<{v}%_work_us", rho, "per-task work (us) needed"))
    return [
        ("table2/S_task_bytes", s_task, "static node size"),
        ("table2/T_task_ns", t_task * 1e9, "amortized task creation"),
        ("table2/T_edge_ns", t_edge * 1e9, "amortized edge creation"),
    ] + rows


if __name__ == "__main__":
    for name, val, derived in bench(200_000):
        print(f"{name},{val:.1f},{derived}")
