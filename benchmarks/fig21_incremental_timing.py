"""Paper Figure 21 / §5.5: incremental timing-propagation workload
(OpenTimer v1 vs v2 paradigm).

A levelized circuit-like DAG is updated incrementally: each iteration
marks a random frontier of gates dirty and re-propagates arrival times to
the affected cone. v1 (OpenMP paradigm) re-runs the FULL levelized graph
with barriers; v2 (taskflow paradigm) builds the affected-cone TDG and runs
it with work stealing — the paper's speedup comes from propagating only
through the cone and not paying level barriers.
"""
from __future__ import annotations

import random
import time
from collections import defaultdict, deque

from repro.core import Executor, Taskflow
from .common import levels_of


def _circuit(n_gates: int, seed: int = 1):
    rng = random.Random(seed)
    edges = []
    for v in range(2, n_gates):
        for u in rng.sample(range(max(0, v - 50), v), min(2, v)):
            edges.append((u, v))
    return edges


def _cone(n, succ, dirty):
    seen = set(dirty)
    q = deque(dirty)
    while q:
        u = q.popleft()
        for v in succ[u]:
            if v not in seen:
                seen.add(v)
                q.append(v)
    return seen


def bench(n_gates: int = 3_000, iters: int = 10, dirty_frac: float = 0.02):
    edges = _circuit(n_gates)
    succ = defaultdict(list)
    pred = defaultdict(list)
    for u, v in edges:
        succ[u].append(v)
        pred[v].append(u)
    at = [0.0] * n_gates          # arrival times
    delay = [random.Random(i).random() for i in range(n_gates)]

    def propagate(v):
        at[v] = delay[v] + max((at[u] for u in pred[v]), default=0.0)

    rng = random.Random(42)
    dirty_sets = [rng.sample(range(n_gates), int(n_gates * dirty_frac))
                  for _ in range(iters)]

    # v1: full levelized re-propagation with barriers every level
    levels = levels_of(n_gates, edges)
    t0 = time.perf_counter()
    for _ in range(iters):
        for level in levels:
            for v in level:
                propagate(v)
    t_v1 = time.perf_counter() - t0

    # v2: affected-cone taskflow per iteration (work stealing, no barriers)
    ex = Executor(domains={"host": 4})
    t0 = time.perf_counter()
    cone_sizes = []
    for dirty in dirty_sets:
        cone = _cone(n_gates, succ, dirty)
        cone_sizes.append(len(cone))
        tf = Taskflow("iter")
        tmap = {}
        for v in sorted(cone):
            tmap[v] = tf.static(lambda v=v: propagate(v))
        for v in cone:
            for u in pred[v]:
                if u in cone:
                    tmap[u].precede(tmap[v])
        ex.run(tf).wait()
    t_v2 = time.perf_counter() - t0
    ex.shutdown(wait=False)

    avg_cone = sum(cone_sizes) / len(cone_sizes)
    return [
        ("fig21/v1_levelized_full_ms", t_v1 * 1e3, "OpenMP paradigm"),
        ("fig21/v2_taskflow_incremental_ms", t_v2 * 1e3,
         "affected-cone TDG"),
        ("fig21/speedup", t_v1 / t_v2, "v2 over v1"),
        ("fig21/avg_cone_gates", avg_cone,
         f"of {n_gates} total"),
    ]


if __name__ == "__main__":
    for name, val, derived in bench():
        print(f"{name},{val:.3f},{derived}")
