# Journal overhead gate (durability goal, not a paper figure): the
# request WAL must be effectively free when attached — and literally one
# `is None` check per transition when it is not.
"""Serve throughput with the request journal ON vs OFF, gated to a budget.

The durability discipline (:mod:`repro.serve.journal`) journals per
request *transition* — submit/admit/first_token/finish — never per
token, so a saturated decode workload should pay almost nothing for it.
This gate proves that: ONE engine runs an identical workload with a
journal attached (``fsync_every=0`` — buffered writes, fsync off the
hot path, matching what a deployment amortizing durability would run;
fsync cost is a disk property, not engine overhead) and detached, and
the attached-path tokens/sec must stay within budget of the detached
path.

Methodology matches the observability gate (`obs_overhead_gate.py`):
repetitions are INTERLEAVED off/on and each mode is scored by its BEST
repetition — deterministic per-transition work survives into the
cleanest rep, shared-container CPU throttling does not. Both modes run
the SAME compiled programs (``ServeEngine.set_journal`` rebinds at
idle; journaling never changes compiled shapes).

Budget: the ``REPRO_JOURNAL_GATE_BUDGET`` env var (fraction, default
0.05 — journal appends hit the filesystem, so the budget is the CI
obs-gate slack, not the local 2%).
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import Iterator, Tuple


def _run(eng, prompts, max_new: int) -> float:
    for k in eng.stats:
        eng.stats[k] = 0
    t0 = time.perf_counter()
    reqs = [eng.submit(p, max_new) for p in prompts]
    for r in reqs:
        eng.result(r, timeout=600.0)
    return time.perf_counter() - t0


def bench(quick: bool = False) -> Iterator[Tuple[str, str, str]]:
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve.engine import ServeEngine
    from repro.serve.journal import Journal

    budget = float(os.environ.get("REPRO_JOURNAL_GATE_BUDGET", "0.05"))
    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    chunk = 4
    n_req = 6
    max_new = 64 if quick else 128
    reps = 5 if quick else 8
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(n_req)]
    total_tokens = n_req * max_new

    samples = {"off": [], "on": []}
    with tempfile.TemporaryDirectory() as td, \
            ServeEngine(cfg, params, decode_chunk=chunk, max_batch=8,
                        kv_blocks=224, block_size=8, prefill_chunk=16,
                        max_seq_len=-(-(8 + max_new) // 8) * 8) as eng:
        # warm-up compiles every program both modes run (identical: the
        # journal is pure python off the device path)
        _run(eng, prompts, max(2, chunk + 1))
        for i in range(reps):
            for mode in ("off", "on"):
                if mode == "on":
                    eng.set_journal(Journal(
                        os.path.join(td, f"rep{i}.wal"), fsync_every=0))
                else:
                    eng.set_journal(None)
                dt = _run(eng, prompts, max_new)
                samples[mode].append(total_tokens / dt)
        eng.set_journal(None)
    off = float(np.max(samples["off"]))
    on = float(np.max(samples["on"]))
    ratio = on / off
    yield ("journal_gate_off_tok_per_s", f"{off:.1f}", f"best_of_{reps}")
    yield ("journal_gate_on_tok_per_s", f"{on:.1f}", f"{ratio:.3f}x_off")
    yield ("journal_gate_overhead_frac", f"{max(0.0, 1.0 - ratio):.4f}",
           f"budget_{budget:.2f}")
    if ratio < 1.0 - budget:
        raise AssertionError(
            f"journal overhead gate failed: journaled path at "
            f"{on:.1f} tok/s vs plain {off:.1f} tok/s "
            f"({(1.0 - ratio) * 100:.1f}% > {budget * 100:.0f}% budget)")
    yield ("journal_gate", "ok", f"within_{budget * 100:.0f}pct")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    for name, val, derived in bench(quick=args.quick):
        print(f"{name},{val},{derived}")
