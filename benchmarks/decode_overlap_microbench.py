# Decode-overlap microbench (ROADMAP production-serve goal, not a paper
# figure): quantify the host gap the async decode lookahead closes.
"""Per-cycle dispatch/sync/bookkeeping breakdown: sync vs async decode.

The synchronous decode stage blocks on every chunk's tokens, re-uploads
the ``lengths``/``last``/``rem`` mirrors every cycle, and runs all
grow/retire/admit bookkeeping while the device idles. The async engine
(``ServeEngine(async_decode=True)``) keeps the carry device-resident and
dispatches chunk N+1 before syncing chunk N, so the host bookkeeping
overlaps device compute. This microbench drives BOTH modes over an
identical saturated greedy-decode workload at several decode-chunk sizes
and reports, per ``(chunk, mode)``:

* wall-clock tokens/sec and the mean per-decode-cycle wall time;
* the breakdown from ``ServeEngine.overlap_stats``: ``dispatch`` (chunk
  launch), ``wait`` (blocking device sync), ``book`` (host bookkeeping);
* the HOST GAP: per-cycle decode-stage wall time NOT covered by device
  compute — the quantity async dispatch exists to shrink. The device time
  is calibrated as the cleanest (minimum) sync-cycle
  upload+launch+block interval, a constant SHARED by both modes (they run
  the same compiled chunk), so ``gap = cycle_ms - device_ms`` and
  ``gap_frac = gap / cycle_ms`` compare the modes on identical footing
  and scheduler/CPU-quota noise cannot flip the comparison's direction.
  The async rows' derived column is the ratio vs sync.

Repetitions are INTERLEAVED sync/async and summarised per-mode by the
median, so CPU-quota throttling and scheduler noise (this is a shared
CPU container) land on both modes alike. Both modes share one engine per
chunk size (the mode flag is toggled at idle, when the device carry and
the host mirrors are identically zero), so they run the SAME compiled
programs. A final parity pass pins
``paged_impl="gather"`` (the bit-exact oracle) and asserts the async
token streams equal the synchronous engine's, chunked prefill included.

The per-cycle numbers are read from the engine's metrics registry
(:mod:`repro.obs` — ``engine.cycle_s`` / ``engine.dispatch_s`` /
``engine.chunk_sync_s`` / ``engine.book_s`` histograms, reset in place
between repetitions; the device-time calibration constant is the min of
the sync-mode ``engine.chunk_s`` histogram), and ``trace_path`` writes
the last timed repetition's Chrome trace-event JSON artifact. The serve
pipeline's per-stage wall-time split (``Pipeline.stage_times``) is
reported for the async engine as an observability cross-check.
"""
from __future__ import annotations

import time
from typing import Iterator, List, Tuple


def _run(eng, prompts, max_new: int) -> Tuple[float, List]:
    """Submit every prompt up front (saturated batch), wait for all."""
    for k in eng.stats:
        eng.stats[k] = 0
    for k in eng.overlap_stats:
        eng.overlap_stats[k] = 0
    if eng.obs is not None:
        eng.obs.reset()     # in place: the engine's cached handles survive
    t0 = time.perf_counter()
    reqs = [eng.submit(p, max_new) for p in prompts]
    outs = [eng.result(r, timeout=600.0) for r in reqs]
    return time.perf_counter() - t0, outs


def bench(quick: bool = False,
          trace_path: str = None) -> Iterator[Tuple[str, str, str]]:
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import lm
    from repro.obs import Observability
    from repro.serve.engine import ServeEngine

    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    chunks = (2, 4, 8) if quick else (1, 2, 4, 8)
    n_req = 6 if quick else 8
    plen = 8
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=plen).astype(np.int32)
               for _ in range(n_req)]
    # per-chunk stream length: enough decode CYCLES per run (~16) that the
    # async mode's fixed one-or-two-cycle tax (drain + one-chunk-late
    # retirement) amortises the way it does on production-length streams
    cycles_target = 16 if quick else 24
    geo = dict(max_batch=8, kv_blocks=224, block_size=8, prefill_chunk=16)

    obs = Observability()
    stage_times = None
    for chunk in chunks:
        max_new = cycles_target * chunk
        total_tokens = n_req * max_new
        geo["max_seq_len"] = -(-(plen + max_new) // 8) * 8
        # ONE engine per chunk size: toggling async_decode at idle is safe
        # (device carry == host mirrors == zero between runs) and keeps the
        # two modes on the SAME compiled chunk/prefill programs
        reps = 3
        with ServeEngine(cfg, params, decode_chunk=chunk,
                         async_decode=False, obs=obs, **geo) as eng:
            samples = {"sync": [], "async": []}
            for mode in ("sync", "async"):
                # per-mode warm-up: compiles the chunk/prefill programs AND
                # the async path's carry scatters, so the timed runs below
                # measure steady-state cycles only
                eng.async_decode = mode == "async"
                _run(eng, prompts, max(2, chunk + 1))
            for _ in range(reps):
                # INTERLEAVED repetitions + per-mode medians: CPU-quota
                # throttling and scheduler noise hit both modes alike
                for mode in ("sync", "async"):
                    eng.async_decode = mode == "async"
                    dt, _ = _run(eng, prompts, max_new)
                    # per-cycle breakdown straight from the registry: the
                    # engine records one histogram sample per decode cycle
                    # exactly where overlap_stats accumulates, so the means
                    # below equal the old sum/cycles arithmetic
                    snap = obs.metrics.snapshot()
                    samples[mode].append({
                        "tok_per_s": total_tokens / dt,
                        # sync-mode cycles only record engine.chunk_s; its
                        # min is the device-time calibration sample
                        "min_chunk_ms": 1e3 * snap["engine.chunk_s"]["min"],
                        "cycle_ms": 1e3 * snap["engine.cycle_s"]["mean"],
                        "disp_ms": 1e3 * snap["engine.dispatch_s"]["mean"],
                        "wait_ms":
                            1e3 * snap["engine.chunk_sync_s"]["mean"],
                        "book_ms": 1e3 * snap["engine.book_s"]["mean"],
                    })
            res = {mode: {k: float(np.median([s[k] for s in runs]))
                          for k in runs[0]}
                   for mode, runs in samples.items()}
            # device-time calibration: the cleanest (least contended)
            # sync-cycle upload+launch+block interval bounds the chunk's
            # device time from above. Host gap per cycle = mean cycle wall
            # time minus this SHARED constant — the canonical "cycle time
            # not covered by device compute", identical for both modes, so
            # contention noise can never flip the comparison direction
            c_ms = min(s["min_chunk_ms"] for s in samples["sync"]
                       if s["min_chunk_ms"] > 0)
            for mode in res:
                res[mode]["gap_ms"] = max(0.0, res[mode]["cycle_ms"] - c_ms)
                res[mode]["gap_frac"] = \
                    res[mode]["gap_ms"] / max(res[mode]["cycle_ms"], 1e-9)
            if eng._pipeline is not None:
                stage_times = eng._pipeline.stage_times
        s, a = res["sync"], res["async"]
        yield (f"overlap_c{chunk}_sync_tok_per_s", f"{s['tok_per_s']:.1f}",
               f"cycle_{s['cycle_ms']:.1f}ms")
        yield (f"overlap_c{chunk}_async_tok_per_s", f"{a['tok_per_s']:.1f}",
               f"{a['tok_per_s'] / s['tok_per_s']:.2f}x_sync")
        yield (f"overlap_c{chunk}_sync_cycle_ms", f"{s['cycle_ms']:.2f}",
               f"disp_{s['disp_ms']:.2f}_wait_{s['wait_ms']:.2f}"
               f"_book_{s['book_ms']:.2f}")
        yield (f"overlap_c{chunk}_async_cycle_ms", f"{a['cycle_ms']:.2f}",
               f"disp_{a['disp_ms']:.2f}_wait_{a['wait_ms']:.2f}"
               f"_book_{a['book_ms']:.2f}")
        yield (f"overlap_c{chunk}_sync_host_gap_frac", f"{s['gap_frac']:.3f}",
               f"gap_{s['gap_ms']:.2f}ms_per_cycle")
        yield (f"overlap_c{chunk}_async_host_gap_frac",
               f"{a['gap_frac']:.3f}",
               f"{a['gap_frac'] / max(s['gap_frac'], 1e-9):.2f}x_sync")
        if chunk <= 4 and a["cycle_ms"] > s["cycle_ms"] * 1.05:
            # regression guard at the chunk sizes where the host gap
            # dominates (generous noise margin — losing the overlap, e.g.
            # an accidental host sync before the dispatch, shows up as a
            # 1.3-2x cycle blowup, and gap_frac is monotone in cycle_ms)
            raise AssertionError(
                f"async decode lost its overlap win at chunk={chunk}: "
                f"{a['cycle_ms']:.2f}ms/cycle vs sync "
                f"{s['cycle_ms']:.2f}ms (gap_frac {a['gap_frac']:.3f} "
                f"vs {s['gap_frac']:.3f})")

    if stage_times is not None:
        yield ("overlap_async_stage_times_s",
               "|".join(f"{k}={v:.2f}" for k, v in stage_times.items()),
               "pipeline_stage_wall_time")
    if trace_path:
        # spans of the LAST timed repetition (the registry/tracer reset
        # between reps keeps the artifact one clean run)
        obs.export(trace_path)
        yield ("overlap_trace_spans", str(len(obs.tracer)), trace_path)

    # parity: async greedy tokens bit-identical to the synchronous engine
    # on the gather oracle, chunked prefill included (one long prompt)
    pchunk = chunks[0]
    mixed = prompts[:2] + [rng.integers(1, cfg.vocab_size, size=24)
                           .astype(np.int32)]
    outs = {}
    for mode in (False, True):
        with ServeEngine(cfg, params, decode_chunk=pchunk,
                         paged_impl="gather", async_decode=mode,
                         **geo) as eng:
            outs[mode] = eng.generate(mixed, max_new=8)
    ok = all(x.tolist() == y.tolist()
             for x, y in zip(outs[False], outs[True]))
    if not ok:
        raise AssertionError(
            "async decode diverged from the synchronous engine on the "
            "gather oracle")
    yield ("overlap_parity_gather", "ok", f"chunk_{pchunk}_3_prompts")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the last timed repetition's Chrome "
                         "trace-event JSON here")
    args = ap.parse_args()
    for name, val, derived in bench(quick=args.quick,
                                    trace_path=args.trace):
        print(f"{name},{val},{derived}")
