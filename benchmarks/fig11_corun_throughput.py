"""Paper Figure 11: throughput of co-running task graphs (weighted speedup)
plus CPU-utilization proxies from the executor profiler.

Weighted speedup = sum_i (t_solo / t_i_in_corun); 1.0 means the corun is as
good as running the programs consecutively (paper's definition from [23]).

Utilization is taken from the profiler's PER-DOMAIN summary (normalized
by every worker that reported any hook, sleepers included — workers that
never won a task still hold their cores).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Executor, Profiler, Taskflow
from .common import random_layered_dag


def _build(tfname, n, edges, work):
    tf = Taskflow(tfname)
    tasks = [tf.static(work) for _ in range(n)]
    for u, v in edges:
        tasks[u].precede(tasks[v])
    return tf


def bench(n_tasks: int = 4_000, coruns=(1, 2, 4, 6)):
    xs = np.ones(1024, np.float32)

    def work():
        (xs + xs).sum()

    n, edges = random_layered_dag(n_tasks, width=64)
    rows = []
    # solo time
    prof = Profiler()
    ex = Executor(domains={"host": 4}, observer=prof)
    tf0 = _build("solo", n, edges, work)
    t0 = time.perf_counter()
    ex.run(tf0).wait()
    t_solo = time.perf_counter() - t0
    ex.shutdown(wait=False)
    rows.append(("fig11/solo_ms", t_solo * 1e3, "baseline"))

    for k in coruns:
        prof = Profiler()
        ex = Executor(domains={"host": 4}, observer=prof)
        tfs = [_build(f"corun{i}", n, edges, work) for i in range(k)]
        t0 = time.perf_counter()
        topos = [ex.run(tf) for tf in tfs]
        for tp in topos:
            tp.wait()
        dt = time.perf_counter() - t0
        s = prof.summary()
        ex.shutdown(wait=False)
        weighted = sum(t_solo / dt for _ in range(k))
        host = s["per_domain"].get("host", s)
        rows += [
            (f"fig11/corun{k}/weighted_speedup", weighted,
             ">=1 is consecutive-equivalent"),
            (f"fig11/corun{k}/utilization", host["utilization"],
             f"host busy fraction over {host.get('workers', 0)} workers"),
            (f"fig11/corun{k}/sleep_residency", host["sleep_residency"],
             "adaptive sleeping"),
            (f"fig11/corun{k}/steals_ok", float(host["steals_ok"]),
             f"{host['steals_fail']}_failed"),
        ]
    return rows


if __name__ == "__main__":
    for name, val, derived in bench():
        print(f"{name},{val:.3f},{derived}")
