"""Paper Figure 13 / §5.3: Large Sparse DNN inference challenge.

The workload: Y <- clamp(relu(Y @ W_l + b_l)) over many layers, batched
over input partitions, with a CPU-side scoring/condition step driving a
data-dependent loop — exactly the paper's decomposition (cudaFlows of
layer kernels + condition tasks for the dispatch loop).

Three implementations:
* taskflow   — condition-task cycle; each pass offloads a DeviceFlow whose
               captured graph runs a BLOCK of layers in one XLA launch;
* levelized  — statically unrolled: one host launch per layer per pass
               (the paper's oneTBB/StarPU-style unrolled TDG);
* sequential — plain loop, one launch per layer (no graph reuse).

Reported: runtime, host launches (the CUDA-Graph-effect metric), peak RSS,
task/graph counts (the paper's memory argument: the cyclic TDG stays
constant-size while unrolled graphs grow with iteration count).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ACCEL, DeviceFlow, Executor, HOST, Taskflow
from repro.kernels.ref import lsdnn_layer_ref
from .common import peak_rss_mb


def _make_net(layers: int, neurons: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    ws = []
    for _ in range(layers):
        w = rng.standard_normal((neurons, neurons)).astype(np.float32) * 0.05
        w[rng.random(w.shape) < 0.7] = 0.0   # sparse weights
        ws.append(w)
    b = rng.standard_normal(neurons).astype(np.float32) * 0.1
    y0 = (rng.random((256, neurons)) < 0.2).astype(np.float32)
    return ws, b, y0


def _block_fn(ws_block, b):
    def f(y):
        for w in ws_block:
            y = lsdnn_layer_ref(y, w, b)
        return y
    return f


def bench(layers: int = 48, neurons: int = 512, block: int = 8,
          passes: int = 3):
    ws, b, y0 = _make_net(layers, neurons)
    rows = []

    # -- sequential: one launch per layer per pass --------------------------
    t0 = time.perf_counter()
    launches = 0
    for _ in range(passes):
        y = jnp.asarray(y0)
        for w in ws:
            y = jax.jit(lsdnn_layer_ref)(y, jnp.asarray(w), jnp.asarray(b))
            launches += 1
        y.block_until_ready()
    t_seq = time.perf_counter() - t0
    ref_out = np.asarray(y)
    rows += [("fig13/sequential_ms", t_seq * 1e3, "per-layer launches"),
             ("fig13/sequential_launches", launches, "host->device calls")]

    # -- levelized/unrolled: one compiled program per LAYER, all passes
    #    unrolled into a flat task list (StarPU/oneTBB-paradigm) ------------
    fns = [jax.jit(_block_fn([w], b)) for w in ws]
    t0 = time.perf_counter()
    launches = 0
    for _ in range(passes):
        y = jnp.asarray(y0)
        for f in fns:
            y = f(y)
            launches += 1
        y.block_until_ready()
    t_lvl = time.perf_counter() - t0
    rows += [("fig13/unrolled_ms", t_lvl * 1e3, "unrolled TDG"),
             ("fig13/unrolled_launches", launches, "host->device calls"),
             ("fig13/unrolled_tasks", passes * layers, "graph size grows")]

    # -- taskflow: conditional cycle + ONE DeviceFlow captured once and
    #    re-offloaded per pass with stateful parameter capture (§3.5.2) ----
    ex = Executor(domains={HOST: 2, ACCEL: 1},
                  devices={ACCEL: jax.devices()[:1]})
    state = {"pass": 0, "y": y0, "launches": 0}
    blocks = [ws[i:i + block] for i in range(0, layers, block)]
    block_fns = [_block_fn(bl, b) for bl in blocks]

    df = DeviceFlow()
    df.copy("y", y0)
    prev = "y"
    for bi, f in enumerate(block_fns):
        df.kernel(f, [prev], [f"y{bi}"])
        prev = f"y{bi}"
    df.fetch(prev)

    tf = Taskflow("lsdnn")
    init = tf.static(lambda: state.update(y=y0))

    def infer():
        df._inputs["y"] = state["y"]      # stateful capture: new input,
        out = df.offload()                # same compiled graph, ONE launch
        state["y"] = out[prev]
        state["launches"] += 1

    t_infer = tf.static(infer, name="infer", domain=ACCEL)

    def score() -> int:
        state["pass"] += 1
        return 1 if state["pass"] >= passes else 0

    cond = tf.condition(score, name="score")
    done = tf.static(lambda: None)
    init.precede(t_infer)
    t_infer.precede(cond)
    cond.precede(t_infer, done)

    df.offload()  # warm-up: compile the captured program (the jitted
    # per-layer baselines above are likewise warm from their first pass)
    t0 = time.perf_counter()
    ex.run(tf).wait()
    t_tf = time.perf_counter() - t0
    ex.shutdown(wait=False)
    got = np.asarray(state["y"])
    err = float(np.max(np.abs(got - ref_out)))
    rows += [
        ("fig13/taskflow_ms", t_tf * 1e3, "cyclic TDG + DeviceFlow"),
        ("fig13/taskflow_launches", state["launches"],
         "ONE launch per pass (CUDA-graph effect)"),
        ("fig13/taskflow_tasks", tf.num_tasks(), "graph size CONSTANT"),
        ("fig13/result_max_err", err, "vs sequential oracle"),
        ("fig13/peak_rss_mb", peak_rss_mb(), "memory panel"),
    ]
    return rows


if __name__ == "__main__":
    for name, val, derived in bench():
        print(f"{name},{val:.3f},{derived}")
